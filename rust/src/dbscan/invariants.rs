//! Machine-checked structural invariants — the executable form of
//! Theorem 2: after every `AddPoint`/`DeletePoint`, `G[C]` is a spanning
//! forest of the collision graph `H`.
//!
//! `verify` recomputes everything from scratch (buckets → H → union-find
//! components) and compares against the incrementally maintained forest.
//! O(n·t) — test/debug only, never on the request path.

use rustc_hash::{FxHashMap, FxHashSet};

use crate::baselines::unionfind::UnionFind;
use crate::lsh::table::PointId;

use super::{Connectivity, DynamicDbscan};

#[derive(Debug, thiserror::Error)]
pub enum InvariantError {
    #[error("core flag mismatch for point {0}: flag={1} but bucket sizes say {2}")]
    CoreFlag(PointId, bool, bool),
    #[error("forest edge between cores {0},{1} that never collide (not an H edge)")]
    NonHEdge(PointId, PointId),
    #[error("cores {0},{1} collide in a bucket but are in different forest components")]
    Disconnected(PointId, PointId),
    #[error("cores {0},{1} in same forest component but different H components")]
    OverConnected(PointId, PointId),
    #[error("non-core point {0} has forest degree {1} > 1")]
    NonCoreDegree(PointId, usize),
    #[error("attachment bookkeeping broken for point {0}")]
    Attachment(PointId),
    #[error("core {0} has forest degree {1} > 2t + attached ({2})")]
    CoreDegree(PointId, usize, usize),
}

impl<C: Connectivity> DynamicDbscan<C> {
    /// Check all Theorem-2 invariants; returns the first violation.
    pub fn verify(&self) -> Result<(), InvariantError> {
        let ids: Vec<PointId> = self.point_ids().collect();
        let index_of: FxHashMap<PointId, usize> =
            ids.iter().enumerate().map(|(i, &p)| (p, i)).collect();

        // 1. core flags match Definition 4
        for &p in &ids {
            let (is_core, _, _, _) = self.point_state(p);
            let should = self
                .point_keys(p)
                .iter()
                .enumerate()
                .any(|(i, &k)| self.tables()[i].bucket(k).len() >= self.cfg.k);
            if is_core != should {
                return Err(InvariantError::CoreFlag(p, is_core, should));
            }
        }

        // 2. H from scratch: union-find over colliding cores; also collect
        // collision sets for edge validation.
        let mut uf = UnionFind::new(ids.len());
        let mut h_pairs: FxHashSet<(PointId, PointId)> = FxHashSet::default();
        for table in self.tables() {
            for (_, b) in table.iter() {
                let cores: Vec<PointId> = b.cores.iter().copied().collect();
                for w in cores.windows(2) {
                    uf.union(index_of[&w[0]], index_of[&w[1]]);
                }
                // all pairs in this bucket are H-edges
                for i in 0..cores.len() {
                    for j in (i + 1)..cores.len() {
                        let (a, b) = (cores[i].min(cores[j]), cores[i].max(cores[j]));
                        h_pairs.insert((a, b));
                    }
                }
            }
        }

        // 3. forest structure vs H
        for &p in &ids {
            let (is_core, attached_to, attached, vertex) = self.point_state(p);
            let deg = self.conn().tree_degree(vertex);
            if !is_core {
                if deg > 1 {
                    return Err(InvariantError::NonCoreDegree(p, deg));
                }
                match attached_to {
                    Some(h) => {
                        let (h_core, _, h_att, hv) = self.point_state(h);
                        if !h_core
                            || !h_att.contains(p)
                            || !self.conn().has_tree_edge(vertex, hv)
                            || deg != 1
                        {
                            return Err(InvariantError::Attachment(p));
                        }
                        // attachment edge must be an H-style collision too
                        // (non-core attaches to a core it collides with)
                        let collide = self.point_keys(p)
                            .iter()
                            .zip(self.point_keys(h))
                            .any(|(a, b)| a == b);
                        if !collide {
                            return Err(InvariantError::Attachment(p));
                        }
                    }
                    None => {
                        if deg != 0 || !attached.is_empty() {
                            return Err(InvariantError::Attachment(p));
                        }
                    }
                }
            } else {
                let max = 2 * self.cfg.t + attached.len();
                if deg > max {
                    return Err(InvariantError::CoreDegree(p, deg, max));
                }
            }
        }

        // 4. every forest edge between two cores must be an H edge
        let cores: Vec<PointId> = ids
            .iter()
            .copied()
            .filter(|&p| self.point_state(p).0)
            .collect();
        for (ai, &a) in cores.iter().enumerate() {
            for &b in cores.iter().skip(ai + 1) {
                let (va, vb) =
                    (self.point_state(a).3, self.point_state(b).3);
                let edge = self.conn().has_tree_edge(va, vb);
                if edge {
                    let key = (a.min(b), a.max(b));
                    if !h_pairs.contains(&key) {
                        return Err(InvariantError::NonHEdge(a, b));
                    }
                }
                // 5. component equality: same H component ⇔ same forest tree
                let same_h = uf.find(index_of[&a]) == uf.find(index_of[&b]);
                let same_f = self.conn().connected(va, vb);
                if same_h && !same_f {
                    return Err(InvariantError::Disconnected(a, b));
                }
                if !same_h && same_f {
                    return Err(InvariantError::OverConnected(a, b));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::{DbscanConfig, DynamicDbscan};
    use crate::dbscan::connectivity::RepairConn;
    use crate::dbscan::leveled::LeveledConn;
    use crate::ett::treap::TreapSeq;
    use crate::ett::TreapForest;
    use crate::util::proptest::{run_prop, Gen};

    /// Which connectivity layer a Theorem-2 scenario drives.
    #[derive(Clone, Copy)]
    enum Mode {
        /// the default: leveled over skip lists
        LeveledSkip,
        /// leveled over the treap backend (cross-check)
        LeveledTreap,
        /// the flat repair ablation over the treap backend
        RepairTreap,
        /// the flat repair ablation over skip lists (the pre-leveled
        /// default, still shipped via `DynamicDbscan::repair_mode` and
        /// benched on the conn ablation axis)
        RepairSkip,
    }

    /// Theorem 2 as a property: invariants hold after EVERY update in a
    /// random interleaving of adds and deletes, on every connectivity
    /// mode × forest backend combination.
    #[test]
    fn theorem2_random_updates_leveled_skiplist() {
        run_prop("theorem 2 leveled skiplist", 25, |g| {
            theorem2_scenario(g, Mode::LeveledSkip)
        });
    }

    #[test]
    fn theorem2_random_updates_leveled_treap() {
        run_prop("theorem 2 leveled treap", 25, |g| {
            theorem2_scenario(g, Mode::LeveledTreap)
        });
    }

    #[test]
    fn theorem2_random_updates_repair_treap() {
        run_prop("theorem 2 repair treap", 25, |g| {
            theorem2_scenario(g, Mode::RepairTreap)
        });
    }

    #[test]
    fn theorem2_random_updates_repair_skiplist() {
        run_prop("theorem 2 repair skiplist", 25, |g| {
            theorem2_scenario(g, Mode::RepairSkip)
        });
    }

    fn theorem2_scenario(g: &mut Gen, mode: Mode) {
        let dim = g.usize_in(1..=3);
        let cfg = DbscanConfig {
            k: g.usize_in(2..=5),
            t: g.usize_in(2..=6),
            eps: g.f64_in(0.2, 1.0) as f32,
            dim,
            eager_attach: g.rng.coin(0.3),
        };
        let seed = g.rng.next_u64();
        // two spatial clusters + background noise
        let mut live: Vec<u64> = Vec::new();
        let ops = g.usize_in(10..=80);
        macro_rules! drive {
            ($db:expr) => {{
                for _ in 0..ops {
                    if live.is_empty() || g.rng.coin(0.65) {
                        let c = g.usize_in(0..=2) as f64 * 3.0;
                        let p: Vec<f32> = (0..dim)
                            .map(|_| (c + g.f64_in(-0.5, 0.5)) as f32)
                            .collect();
                        live.push($db.add_point(&p));
                    } else {
                        let i = g.usize_in(0..=live.len() - 1);
                        let p = live.swap_remove(i);
                        $db.delete_point(p);
                    }
                    if let Err(e) = $db.verify() {
                        panic!("invariant violated after op: {e}");
                    }
                }
            }};
        }
        match mode {
            Mode::LeveledSkip => {
                let mut db = DynamicDbscan::new(cfg, seed);
                drive!(db);
            }
            Mode::LeveledTreap => {
                let mut db = DynamicDbscan::with_conn(
                    cfg,
                    seed,
                    LeveledConn::<TreapSeq>::new(seed ^ 1),
                );
                drive!(db);
            }
            Mode::RepairTreap => {
                let mut db = DynamicDbscan::with_conn(
                    cfg,
                    seed,
                    RepairConn::new(TreapForest::new(seed ^ 1)),
                );
                drive!(db);
            }
            Mode::RepairSkip => {
                let mut db = DynamicDbscan::repair_mode(cfg, seed);
                drive!(db);
            }
        }
    }

    /// Documents the soundness gap in the paper's verbatim Algorithm 2
    /// (see `connectivity` module docs): the minimal 4-op counterexample
    /// violates Theorem 2 in paper-exact mode, while the default leveled
    /// mode maintains it. The exact counterexample depends on the drawn η
    /// shifts, so we search nearby workloads for a violating run; the
    /// default structure must stay clean on every one of them.
    #[test]
    fn paper_exact_violates_theorem2_leveled_does_not() {
        let cfg = DbscanConfig {
            k: 2,
            t: 2,
            eps: 0.4,
            dim: 1,
            eager_attach: false,
        };
        let mut violated = false;
        for seed in 0..200 {
            let mut rng = crate::util::rng::Rng::new(seed);
            let mut paper = DynamicDbscan::paper_exact(cfg.clone(), seed);
            let mut fixed = DynamicDbscan::new(cfg.clone(), seed);
            let mut live: Vec<(u64, u64)> = Vec::new();
            for _ in 0..60 {
                if live.is_empty() || rng.coin(0.65) {
                    let c = rng.below(3) as f64 * 3.0;
                    let p = [(c + rng.uniform(-0.5, 0.5)) as f32];
                    live.push((paper.add_point(&p), fixed.add_point(&p)));
                } else {
                    let i = rng.below_usize(live.len());
                    let (pp, pf) = live.swap_remove(i);
                    paper.delete_point(pp);
                    fixed.delete_point(pf);
                }
                fixed.verify().expect("leveled mode must satisfy Theorem 2");
                if paper.verify().is_err() {
                    violated = true;
                }
            }
            if violated {
                break;
            }
        }
        assert!(
            violated,
            "expected to reproduce the paper's Theorem-2 violation \
             (if this fails, the counterexample search needs widening)"
        );
    }

    /// Order invariance: inserting the same point set in two different
    /// orders yields the same partition of the points (H is order-free).
    #[test]
    fn clustering_is_order_invariant() {
        run_prop("order invariance", 20, |g| {
            let dim = 2;
            let n = g.usize_in(5..=40);
            let pts: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let c = g.usize_in(0..=2) as f64 * 2.5;
                    (0..dim).map(|_| (c + g.f64_in(-0.4, 0.4)) as f32).collect()
                })
                .collect();
            let cfg = DbscanConfig {
                k: 3,
                t: 4,
                eps: 0.5,
                dim,
                eager_attach: false,
            };
            let seed = g.rng.next_u64();
            // same hash functions (same seed) — only insertion order differs
            let mut a = DynamicDbscan::new(cfg.clone(), seed);
            let ida: Vec<u64> = pts.iter().map(|p| a.add_point(p)).collect();
            let mut order: Vec<usize> = (0..n).collect();
            g.rng.shuffle(&mut order);
            let mut b = DynamicDbscan::new(cfg, seed);
            let mut idb = vec![0u64; n];
            for &i in &order {
                idb[i] = b.add_point(&pts[i]);
            }
            // compare partitions restricted to CORE points (Theorem 2 scope:
            // non-core attachment is explicitly order-dependent)
            for i in 0..n {
                assert_eq!(
                    a.is_core(ida[i]),
                    b.is_core(idb[i]),
                    "core set differs at {i}"
                );
            }
            for i in 0..n {
                for j in (i + 1)..n {
                    if a.is_core(ida[i]) && a.is_core(ida[j]) {
                        assert_eq!(
                            a.get_cluster(ida[i]) == a.get_cluster(ida[j]),
                            b.get_cluster(idb[i]) == b.get_cluster(idb[j]),
                            "pair ({i},{j}) clustered differently"
                        );
                    }
                }
            }
        });
    }

    /// Delete/re-insert round-trip: removing a batch and re-adding points
    /// with the same coordinates restores the same core partition.
    #[test]
    fn delete_reinsert_roundtrip() {
        run_prop("delete/reinsert roundtrip", 15, |g| {
            let dim = 2;
            let cfg = DbscanConfig {
                k: 3,
                t: 4,
                eps: 0.5,
                dim,
                eager_attach: false,
            };
            let seed = g.rng.next_u64();
            let n = g.usize_in(8..=30);
            let pts: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let c = g.usize_in(0..=1) as f64 * 3.0;
                    vec![
                        (c + g.f64_in(-0.4, 0.4)) as f32,
                        (c + g.f64_in(-0.4, 0.4)) as f32,
                    ]
                })
                .collect();
            let mut db = DynamicDbscan::new(cfg, seed);
            let ids: Vec<u64> = pts.iter().map(|p| db.add_point(p)).collect();
            let before: Vec<bool> = ids.iter().map(|&i| db.is_core(i)).collect();
            // delete a random subset, then re-insert the same coordinates
            let mut subset: Vec<usize> = (0..n).collect();
            g.rng.shuffle(&mut subset);
            let del = &subset[..g.usize_in(1..=n)];
            for &i in del {
                db.delete_point(ids[i]);
            }
            db.verify().unwrap();
            let mut new_ids = ids.clone();
            for &i in del {
                new_ids[i] = db.add_point(&pts[i]);
            }
            db.verify().unwrap();
            let after: Vec<bool> =
                new_ids.iter().map(|&i| db.is_core(i)).collect();
            assert_eq!(before, after, "core set not restored by round-trip");
        });
    }
}
