//! Leveled dynamic connectivity — Holm–de Lichtenberg–Thorup edge levels
//! over Euler tour forests. The production default [`Connectivity`].
//!
//! ## Why
//!
//! [`super::connectivity::RepairConn`] keeps the spanning forest of the
//! desired-edge multigraph correct, but its replacement search after a
//! tree-edge cut walks the smaller component — `O(min-component)`, i.e.
//! `O(n)` on adversarial path-shaped workloads (see the chain-churn bench
//! in `bench_updates`). The paper's `O(d log³n + log⁴n)` update bound
//! (Theorem 1) presupposes polylogarithmic dynamic connectivity. HDT edge
//! levels (Holm, de Lichtenberg & Thorup, J.ACM '01 — also the backbone of
//! the Tseng–Dhulipala–Blelloch '19 batch-parallel forests our skip-list
//! backend follows) close that gap: `O(log² n)` amortized per edge update.
//!
//! ## Structure
//!
//! Every distinct desired edge carries a **level** `ℓ(e) ∈ 0..⌈log₂ n⌉`,
//! starting at 0 and only ever increasing. The structure keeps a hierarchy
//! of Euler-tour forests `F0 ⊇ F1 ⊇ …` where `Fℓ` contains exactly the
//! tree edges of level ≥ ℓ; `F0` is the spanning forest all queries read.
//! Two invariants:
//!
//! 1. a level-ℓ non-tree edge has both endpoints in one `Fℓ` tree;
//! 2. every `Fℓ` tree has ≤ `n/2^ℓ` vertices — so levels stay `O(log n)`.
//!
//! Cutting a tree edge of level ℓ removes it from `F0..=Fℓ` and searches
//! for a replacement from level ℓ **down** to 0. At level `l` the smaller
//! of the two separated `Fl` trees is processed: its level-`l` tree edges
//! move to `l+1` (allowed by invariant 2 — the smaller side is at most
//! half), then its level-`l` non-tree edges are scanned; one that crosses
//! the cut is promoted to a tree edge at level `l` (relinking `F0..=Fl`),
//! and each one that does not is pushed to level `l+1`. Every scanned edge
//! either ends the search or rises a level it can never descend from, so
//! each edge is charged `O(log n)` times — `O(log² n)` amortized.
//!
//! ## Why the aggregates live in the `Sequence` trait
//!
//! "The level-`l` tree edges of this tree" and "a vertex of this tree with
//! a level-`l` non-tree edge" must be enumerable in `O(log n)` per item —
//! walking the tour would reintroduce the `O(component)` cost this module
//! exists to remove. Both are per-node facts about tour elements (edge
//! arcs and loop arcs), so the tour containers themselves maintain them as
//! OR-aggregates bubbled through every join/split: [`MARK_EDGE`] on the
//! canonical arc of a level-`l` tree edge in `Fl`, [`MARK_VERTEX`] on the
//! loop arc of a vertex with level-`l` non-tree edges in `Fl`
//! ([`Sequence::find_marked`]). All three backends (treap, skip list,
//! naive oracle) implement the augmented API, so the leveled structure is
//! backend-generic exactly like [`EulerForest`].
//!
//! [`MARK_EDGE`]: crate::ett::MARK_EDGE
//! [`MARK_VERTEX`]: crate::ett::MARK_VERTEX
//! [`Sequence::find_marked`]: crate::ett::Sequence::find_marked

use rustc_hash::{FxHashMap, FxHashSet};

use crate::ett::{EulerForest, Forest, SeedableSequence, VertexId};

use super::connectivity::{ekey, Connectivity, RepairStats};

/// Per distinct desired edge: reference count, HDT level, tree/non-tree.
struct EdgeInfo {
    mult: u32,
    level: u8,
    tree: bool,
}

/// One level's non-tree adjacency: endpoint → peer set.
type NtAdj = FxHashMap<VertexId, FxHashSet<VertexId>>;

/// HDT-leveled spanning forests of the desired-edge multigraph. Drop-in
/// [`Connectivity`] with the same desire/undesire semantics as
/// `RepairConn` and `O(log² n)` amortized replacement search.
pub struct LeveledConn<S: SeedableSequence> {
    /// `F0..=F_L`; `Fℓ` holds the tree edges of level ≥ ℓ. Forests above 0
    /// mirror vertex ids allocated by `F0` (lazily, on first touch).
    levels: Vec<EulerForest<S>>,
    /// per level: non-tree desired edges by endpoint (mirrored into the
    /// `MARK_VERTEX` aggregates of that level's forest)
    nt_at: Vec<NtAdj>,
    edges: FxHashMap<(VertexId, VertexId), EdgeInfo>,
    nt_count: usize,
    seed: u64,
    stats: RepairStats,
    /// stable-component tracking (see [`Connectivity::comp_id`]): off by
    /// default so the single-instance path pays nothing; the sharded
    /// serving workers and the cross-shard stitch graph enable it
    track_comps: bool,
    /// stable component id per F0 vertex (valid only while tracking; slots
    /// are overwritten on vertex-id reuse)
    comp: Vec<u64>,
    next_comp: u64,
    /// vertices whose comp id changed since the last drain (duplicates and
    /// since-removed vertices possible — consumers filter)
    comp_changed: Vec<VertexId>,
    comp_scratch: Vec<VertexId>,
    /// time the replacement search into `search_ns` (obs `level_promotion`
    /// stage); off by default so the untimed path never reads a clock
    time_stages: bool,
    /// accumulated replacement-search nanoseconds since the last
    /// [`Connectivity::take_search_ns`]
    search_ns: u64,
}

impl<S: SeedableSequence> LeveledConn<S> {
    pub fn new(seed: u64) -> Self {
        LeveledConn {
            levels: vec![EulerForest::with_backend(S::from_seed(seed))],
            nt_at: vec![FxHashMap::default()],
            edges: FxHashMap::default(),
            nt_count: 0,
            seed,
            stats: RepairStats::default(),
            track_comps: false,
            comp: Vec::new(),
            next_comp: 0,
            comp_changed: Vec::new(),
            comp_scratch: Vec::new(),
            time_stages: false,
            search_ns: 0,
        }
    }

    fn fresh_comp(&mut self) -> u64 {
        self.next_comp += 1;
        self.next_comp
    }

    fn comp_set(&mut self, v: VertexId, c: u64) {
        let i = v as usize;
        if i >= self.comp.len() {
            self.comp.resize(i + 1, 0);
        }
        self.comp[i] = c;
    }

    /// Move every vertex of `loser`'s F0 tree to component `to`, recording
    /// the changes. O(loser-side size) — charged to the vertices whose
    /// cluster identity genuinely changed (they must be relabeled by any
    /// consumer regardless).
    fn comp_absorb(&mut self, loser: VertexId, to: u64) {
        let mut buf = std::mem::take(&mut self.comp_scratch);
        buf.clear();
        self.levels[0].for_each_tree_vertex(loser, &mut |w| buf.push(w));
        for &w in &buf {
            self.comp_set(w, to);
            self.comp_changed.push(w);
        }
        buf.clear();
        self.comp_scratch = buf;
    }

    fn ensure_level(&mut self, l: usize) {
        while self.levels.len() <= l {
            let i = self.levels.len() as u64;
            let seed = self.seed ^ i.wrapping_mul(0x9e37_79b9_7f4a_7c15);
            self.levels.push(EulerForest::with_backend(S::from_seed(seed)));
            self.nt_at.push(FxHashMap::default());
        }
    }

    /// Record {u,v} as a level-`l` non-tree edge, keeping the vertex marks
    /// of `Fl` in sync.
    fn nt_insert(&mut self, l: usize, u: VertexId, v: VertexId) {
        self.ensure_level(l);
        self.levels[l].ensure_vertex(u);
        self.levels[l].ensure_vertex(v);
        for (a, b) in [(u, v), (v, u)] {
            let s = self.nt_at[l].entry(a).or_default();
            s.insert(b);
            if s.len() == 1 {
                self.levels[l].set_vertex_mark(a, true);
            }
        }
        self.nt_count += 1;
    }

    fn nt_remove(&mut self, l: usize, u: VertexId, v: VertexId) {
        for (a, b) in [(u, v), (v, u)] {
            let s = self.nt_at[l].get_mut(&a).expect("nt_remove: missing endpoint");
            let had = s.remove(&b);
            debug_assert!(had, "nt_remove: edge ({u},{v}) not at level {l}");
            if s.is_empty() {
                self.nt_at[l].remove(&a);
                self.levels[l].set_vertex_mark(a, false);
            }
        }
        self.nt_count -= 1;
    }

    /// Make {u,v} a tree edge at `level`: linked into `F0..=level`, with
    /// its search mark set in `F_level`.
    fn tree_link_at(&mut self, level: usize, u: VertexId, v: VertexId) {
        self.ensure_level(level);
        for l in 0..=level {
            let f = &mut self.levels[l];
            if l > 0 {
                f.ensure_vertex(u);
                f.ensure_vertex(v);
            }
            let linked = f.link(u, v);
            debug_assert!(linked, "cycle while linking ({u},{v}) into F{l}");
        }
        self.levels[level].set_edge_mark(u, v, true);
    }

    /// O(log n) fast path: if hinted edge {a,b} is a non-tree desire **at
    /// the cut edge's level** that the cut disconnected, promote it.
    /// Ending up in different `F0` trees means it crosses exactly this cut
    /// (its endpoints were `F0`-connected before). The level-equality
    /// requirement is what makes the shortcut sound: a level-`cut` NT edge
    /// shares an `F_cut` tree (invariant 1), that tree must contain the
    /// cut edge (else a–b would still be `F_cut` ⊆ `F0` connected), so a
    /// and b sit in the two cut halves of **every** `Fℓ`, ℓ ≤ cut — the
    /// promotion reconnects exactly what the cut split, restoring both
    /// invariants at all levels. A lower-level hint has no such guarantee
    /// (its endpoints need not lie in the two halves of the still-split
    /// intermediate forests), so it falls through to the descending
    /// search, which clears those levels properly and will reach it.
    fn try_promote_hint(&mut self, a: VertexId, b: VertexId, cut_level: usize) -> bool {
        let key = ekey(a, b);
        let Some(e) = self.edges.get(&key) else { return false };
        if e.tree
            || e.level as usize != cut_level
            || self.levels[0].connected(a, b)
        {
            return false;
        }
        self.nt_remove(cut_level, a, b);
        self.tree_link_at(cut_level, a, b);
        self.edges.get_mut(&key).unwrap().tree = true;
        self.stats.replacements += 1;
        true
    }

    /// After cutting tree edge (u,v) of level `level` out of
    /// `F0..=F_level`: find a replacement. Hints first (Algorithm 2's
    /// local rewiring patterns — the common case, O(log n)), then the HDT
    /// search from `level` down to 0.
    fn replace(
        &mut self,
        u: VertexId,
        v: VertexId,
        level: usize,
        hints: &[(VertexId, VertexId)],
    ) {
        self.stats.searches += 1;
        let sw = crate::obs::PhaseClock::maybe(self.time_stages);
        for &(a, b) in hints {
            if self.try_promote_hint(a, b, level) {
                if let Some(mut sw) = sw {
                    self.search_ns += sw.lap();
                }
                return;
            }
        }
        for l in (0..=level).rev() {
            if self.search_level(l, u, v) {
                break;
            }
        }
        if let Some(mut sw) = sw {
            self.search_ns += sw.lap();
        }
    }

    /// One level of the HDT replacement search. Returns true when a
    /// replacement was promoted (search over).
    fn search_level(&mut self, l: usize, u: VertexId, v: VertexId) -> bool {
        let (su, sv) = (
            self.levels[l].component_size(u),
            self.levels[l].component_size(v),
        );
        let (small, other) = if su <= sv { (u, v) } else { (v, u) };
        // 1. level-l tree edges of the smaller side rise to l+1 (invariant
        // 2 allows it: the smaller side is at most half the old tree).
        // Tree edges first, so the whole side is F_{l+1}-connected before
        // any non-tree edge follows it up.
        while let Some((a, b)) = self.levels[l].find_marked_edge(small) {
            self.levels[l].set_edge_mark(a, b, false);
            self.ensure_level(l + 1);
            self.levels[l + 1].ensure_vertex(a);
            self.levels[l + 1].ensure_vertex(b);
            let linked = self.levels[l + 1].link(a, b);
            debug_assert!(linked, "push of tree edge ({a},{b}) closed a cycle");
            self.levels[l + 1].set_edge_mark(a, b, true);
            self.edges.get_mut(&ekey(a, b)).unwrap().level = (l + 1) as u8;
            self.stats.pushes += 1;
        }
        // 2. scan the level-l non-tree edges hanging off the smaller side:
        // promote the first that crosses, push the rest up.
        let other_root = self.levels[l].root(other);
        while let Some(x) = self.levels[l].find_marked_vertex(small) {
            let Some(set) = self.nt_at[l].get(&x) else {
                debug_assert!(false, "marked vertex {x} has no level-{l} NT edges");
                break;
            };
            let cands: Vec<VertexId> = set.iter().copied().collect();
            for y in cands {
                self.stats.visited += 1;
                if self.levels[l].root(y) == other_root {
                    // replacement: reconnects F0..=Fl (the forests above l
                    // legitimately stay split)
                    self.nt_remove(l, x, y);
                    self.tree_link_at(l, x, y);
                    self.edges.get_mut(&ekey(x, y)).unwrap().tree = true;
                    self.stats.replacements += 1;
                    return true;
                }
                // both endpoints in the smaller side: rises to l+1 (its
                // tree there is connected — step 1 ran first)
                self.nt_remove(l, x, y);
                self.nt_insert(l + 1, x, y);
                self.edges.get_mut(&ekey(x, y)).unwrap().level = (l + 1) as u8;
                self.stats.pushes += 1;
            }
        }
        false
    }
}

impl<S: SeedableSequence> Connectivity for LeveledConn<S> {
    fn add_vertex(&mut self) -> VertexId {
        let v = self.levels[0].add_vertex();
        if self.track_comps {
            let c = self.fresh_comp();
            self.comp_set(v, c);
        }
        v
    }

    fn remove_vertex(&mut self, v: VertexId) {
        debug_assert!(
            self.nt_at.iter().all(|m| !m.contains_key(&v)),
            "removing vertex {v} with live non-tree edges"
        );
        // mirrors first (they never recycle ids), then the allocator
        for f in self.levels.iter_mut().skip(1) {
            if f.has_vertex(v) {
                f.retire_vertex(v);
            }
        }
        self.levels[0].remove_vertex(v);
    }

    fn desire(&mut self, u: VertexId, v: VertexId) {
        debug_assert_ne!(u, v);
        let key = ekey(u, v);
        if let Some(e) = self.edges.get_mut(&key) {
            e.mult += 1;
            return;
        }
        if self.track_comps && !self.levels[0].connected(u, v) {
            // genuine component merge: the smaller side adopts the larger
            // side's stable id, so relabel cost lands on the side that
            // actually changed cluster identity
            let (su, sv) = (
                self.levels[0].component_size(u),
                self.levels[0].component_size(v),
            );
            let (winner, loser) = if su >= sv { (u, v) } else { (v, u) };
            let to = self.comp[winner as usize];
            self.comp_absorb(loser, to);
        }
        // fresh desires enter at level 0: tree if they connect, else NT
        let tree = self.levels[0].link(u, v);
        if tree {
            self.levels[0].set_edge_mark(u, v, true);
        } else {
            self.nt_insert(0, u, v);
        }
        self.edges.insert(key, EdgeInfo { mult: 1, level: 0, tree });
    }

    fn undesire_hinted(
        &mut self,
        u: VertexId,
        v: VertexId,
        hints: &[(VertexId, VertexId)],
    ) {
        let key = ekey(u, v);
        let Some(e) = self.edges.get_mut(&key) else {
            debug_assert!(false, "undesire of non-desired edge ({u},{v})");
            return;
        };
        e.mult -= 1;
        if e.mult > 0 {
            return;
        }
        let info = self.edges.remove(&key).unwrap();
        let level = info.level as usize;
        if !info.tree {
            self.nt_remove(level, u, v);
            return;
        }
        self.levels[level].set_edge_mark(u, v, false);
        for l in (0..=level).rev() {
            let cut = self.levels[l].cut(u, v);
            debug_assert!(cut, "tree edge ({u},{v}) missing from F{l}");
        }
        self.replace(u, v, level, hints);
        if self.track_comps && !self.levels[0].connected(u, v) {
            // genuine split (no replacement existed): the smaller side
            // becomes a fresh component; transient cut-and-relink
            // patterns (Algorithm 2's rewiring) reconnect above and never
            // reach this point
            let (su, sv) = (
                self.levels[0].component_size(u),
                self.levels[0].component_size(v),
            );
            let small = if su <= sv { u } else { v };
            let c = self.fresh_comp();
            self.comp_absorb(small, c);
        }
    }

    fn root(&self, v: VertexId) -> u64 {
        self.levels[0].root(v)
    }

    fn component_size(&self, v: VertexId) -> usize {
        self.levels[0].component_size(v)
    }

    fn tree_degree(&self, v: VertexId) -> usize {
        self.levels[0].degree(v)
    }

    fn has_tree_edge(&self, u: VertexId, v: VertexId) -> bool {
        self.levels[0].has_edge(u, v)
    }

    fn is_desired(&self, u: VertexId, v: VertexId) -> bool {
        self.edges.contains_key(&ekey(u, v))
    }

    fn live_vertices(&self) -> usize {
        self.levels[0].num_vertices()
    }

    fn live_vertices_per_level(&self) -> Vec<usize> {
        self.levels.iter().map(|f| f.live_vertex_count()).collect()
    }

    fn repair_stats(&self) -> RepairStats {
        RepairStats {
            nt_edges: self.nt_count,
            levels: self.levels.len(),
            ..self.stats
        }
    }

    fn set_comp_tracking(&mut self, on: bool) {
        assert_eq!(
            self.levels[0].num_vertices(),
            0,
            "comp tracking must be toggled on an empty structure"
        );
        self.track_comps = on;
    }

    fn comp_id(&self, v: VertexId) -> u64 {
        if self.track_comps {
            self.comp[v as usize]
        } else {
            self.levels[0].root(v)
        }
    }

    fn drain_comp_changes(&mut self, f: &mut dyn FnMut(VertexId)) {
        for v in self.comp_changed.drain(..) {
            f(v);
        }
    }

    fn edge_count(&self) -> usize {
        self.edges.len()
    }

    fn set_stage_timing(&mut self, on: bool) {
        self.time_stages = on;
    }

    fn take_search_ns(&mut self) -> u64 {
        std::mem::take(&mut self.search_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::super::connectivity::testoracle::GraphOracle;
    use super::*;
    use crate::ett::skiplist::SkipSeq;
    use crate::ett::treap::TreapSeq;
    use crate::util::proptest::{run_prop, Gen};

    /// LeveledConn must track multigraph connectivity exactly under random
    /// desire/undesire churn — on both sequence backends.
    fn leveled_matches_graph_oracle<S: SeedableSequence>() {
        run_prop("leveled conn vs graph oracle", 60, |g: &mut Gen| {
            let n = g.usize_in(2..=16);
            let mut c = LeveledConn::<S>::new(g.rng.next_u64());
            let vs: Vec<VertexId> = (0..n).map(|_| c.add_vertex()).collect();
            let mut o = GraphOracle::new(n);
            let mut desired: Vec<(usize, usize)> = Vec::new();
            for _ in 0..g.usize_in(1..=120) {
                if desired.is_empty() || g.rng.coin(0.6) {
                    let a = g.usize_in(0..=n - 1);
                    let mut b = g.usize_in(0..=n - 1);
                    if a == b {
                        b = (b + 1) % n;
                    }
                    c.desire(vs[a], vs[b]);
                    o.desire(a, b);
                    desired.push((a, b));
                } else {
                    let i = g.usize_in(0..=desired.len() - 1);
                    let (a, b) = desired.swap_remove(i);
                    c.undesire(vs[a], vs[b]);
                    o.undesire(a, b);
                }
                for a in 0..n {
                    for b in 0..n {
                        assert_eq!(
                            c.connected(vs[a], vs[b]),
                            o.connected(a, b),
                            "connectivity({a},{b}) diverged"
                        );
                    }
                }
            }
            // retract everything: every level must drain completely
            while let Some((a, b)) = desired.pop() {
                c.undesire(vs[a], vs[b]);
            }
            assert_eq!(c.repair_stats().nt_edges, 0);
            for &v in &vs {
                c.remove_vertex(v);
            }
            let per_level = c.live_vertices_per_level();
            assert!(
                per_level.iter().all(|&x| x == 0),
                "leaked level vertices: {per_level:?}"
            );
        });
    }

    #[test]
    fn leveled_skiplist_matches_graph_oracle() {
        leveled_matches_graph_oracle::<SkipSeq>();
    }

    #[test]
    fn leveled_treap_matches_graph_oracle() {
        leveled_matches_graph_oracle::<TreapSeq>();
    }

    #[test]
    fn multiplicity_keeps_edge_alive() {
        let mut c = LeveledConn::<TreapSeq>::new(1);
        let a = c.add_vertex();
        let b = c.add_vertex();
        c.desire(a, b);
        c.desire(a, b);
        c.undesire(a, b);
        assert!(c.connected(a, b), "edge must survive one undesire");
        c.undesire(a, b);
        assert!(!c.connected(a, b));
    }

    #[test]
    fn replacement_promotes_nt_edge() {
        // triangle: a-b, b-x tree; a-x non-tree. Cutting a-b promotes a-x.
        let mut c = LeveledConn::<TreapSeq>::new(2);
        let a = c.add_vertex();
        let b = c.add_vertex();
        let x = c.add_vertex();
        c.desire(a, b);
        c.desire(b, x);
        c.desire(a, x);
        assert_eq!(c.repair_stats().nt_edges, 1);
        c.undesire(a, b);
        assert!(c.connected(a, b), "replacement search must reconnect");
        let st = c.repair_stats();
        assert_eq!(st.nt_edges, 0);
        assert_eq!(st.replacements, 1);
    }

    #[test]
    fn hint_short_circuits_the_search() {
        let mut c = LeveledConn::<SkipSeq>::new(3);
        let a = c.add_vertex();
        let b = c.add_vertex();
        let x = c.add_vertex();
        c.desire(a, b);
        c.desire(b, x);
        c.desire(a, x); // NT
        c.undesire_hinted(a, b, &[(a, x)]);
        let st = c.repair_stats();
        assert!(c.connected(a, b));
        assert_eq!(st.replacements, 1);
        assert_eq!(st.visited, 0, "hint must preempt the level scan");
    }

    /// Stable component ids: merges keep the larger side's id, splits mint
    /// a fresh id for the smaller side, transient cut-and-relink emits no
    /// events, and `comp_id` agrees with connectivity throughout — checked
    /// against the graph oracle under random churn.
    #[test]
    fn comp_tracking_matches_connectivity_and_is_stable() {
        run_prop("comp tracking vs oracle", 40, |g: &mut Gen| {
            let n = g.usize_in(2..=14);
            let mut c = LeveledConn::<SkipSeq>::new(g.rng.next_u64());
            c.set_comp_tracking(true);
            let vs: Vec<VertexId> = (0..n).map(|_| c.add_vertex()).collect();
            let mut o = GraphOracle::new(n);
            let mut desired: Vec<(usize, usize)> = Vec::new();
            for _ in 0..g.usize_in(1..=80) {
                if desired.is_empty() || g.rng.coin(0.6) {
                    let a = g.usize_in(0..=n - 1);
                    let mut b = g.usize_in(0..=n - 1);
                    if a == b {
                        b = (b + 1) % n;
                    }
                    c.desire(vs[a], vs[b]);
                    o.desire(a, b);
                    desired.push((a, b));
                } else {
                    let i = g.usize_in(0..=desired.len() - 1);
                    let (a, b) = desired.swap_remove(i);
                    c.undesire(vs[a], vs[b]);
                    o.undesire(a, b);
                }
                // comp ids must induce exactly the oracle's partition
                for a in 0..n {
                    for b in 0..n {
                        assert_eq!(
                            c.comp_id(vs[a]) == c.comp_id(vs[b]),
                            o.connected(a, b),
                            "comp partition diverged at ({a},{b})"
                        );
                    }
                }
            }
            c.drain_comp_changes(&mut |_| {});
        });
    }

    /// Directed check of the change-event contract: the side that adopts
    /// a new id is reported; the surviving (larger) side is not.
    #[test]
    fn comp_events_cover_exactly_the_relabeled_side() {
        let mut c = LeveledConn::<SkipSeq>::new(9);
        c.set_comp_tracking(true);
        let a = c.add_vertex();
        let b = c.add_vertex();
        let z = c.add_vertex();
        let x = c.add_vertex();
        let y = c.add_vertex();
        c.desire(a, b);
        c.desire(a, z); // {a,b,z}
        c.desire(x, y); // {x,y}
        c.drain_comp_changes(&mut |_| {});
        let big = c.comp_id(a);
        assert_eq!(c.comp_id(b), big);
        assert_eq!(c.comp_id(z), big);
        let small = c.comp_id(x);
        assert_eq!(c.comp_id(y), small);
        assert_ne!(small, big);
        // merge: {x,y} is the smaller side — exactly x and y are
        // reported, and the merged comp keeps the larger side's id
        c.desire(a, x);
        let mut changed = Vec::new();
        c.drain_comp_changes(&mut |v| changed.push(v));
        changed.sort_unstable();
        let mut want = vec![x, y];
        want.sort_unstable();
        assert_eq!(changed, want);
        assert_eq!(c.comp_id(x), big);
        assert_eq!(c.comp_id(y), big);
        // genuine split (no replacement exists): the smaller side {x,y}
        // gets a fresh id; {a,b,z} keeps `big`
        c.undesire(a, x);
        let mut changed = Vec::new();
        c.drain_comp_changes(&mut |v| changed.push(v));
        changed.sort_unstable();
        assert_eq!(changed, want);
        assert_eq!(c.comp_id(a), big);
        assert_eq!(c.comp_id(b), big);
        assert_eq!(c.comp_id(z), big);
        assert_eq!(c.comp_id(x), c.comp_id(y));
        assert_ne!(c.comp_id(x), big);
    }

    /// A failed search on a path pushes the smaller side's tree edges up a
    /// level; the hierarchy grows and later drains to nothing.
    #[test]
    fn failed_search_pushes_edges_up_and_drains() {
        let mut c = LeveledConn::<SkipSeq>::new(4);
        let n = 6;
        let vs: Vec<VertexId> = (0..n).map(|_| c.add_vertex()).collect();
        for w in vs.windows(2) {
            c.desire(w[0], w[1]);
        }
        // cut the middle: no replacement exists; the 3-vertex side's two
        // level-0 tree edges rise to level 1
        c.undesire(vs[2], vs[3]);
        assert!(!c.connected(vs[0], vs[5]));
        let st = c.repair_stats();
        assert_eq!(st.replacements, 0);
        assert!(st.pushes >= 2, "expected ≥2 tree-edge pushes, got {}", st.pushes);
        assert!(st.levels >= 2, "hierarchy should have grown");
        // relink and re-cut: the pushed edges are no longer level-0 work
        let pushes_before = st.pushes;
        c.desire(vs[2], vs[3]);
        c.undesire(vs[2], vs[3]);
        let st = c.repair_stats();
        assert!(
            st.pushes <= pushes_before + 2,
            "re-cut must not rescan already-pushed edges"
        );
        // drain
        for w in vs.windows(2) {
            if c.is_desired(w[0], w[1]) {
                c.undesire(w[0], w[1]);
            }
        }
        for &v in &vs {
            c.remove_vertex(v);
        }
        let per_level = c.live_vertices_per_level();
        assert!(per_level.iter().all(|&x| x == 0), "leak: {per_level:?}");
    }
}
