//! Flat slab arena for point storage — the allocation-free hot-path
//! backing store of [`super::DynamicDbscan`].
//!
//! Every live point occupies one *slot*; all per-point data lives in
//! parallel struct-of-arrays vectors indexed by slot:
//!
//! ```text
//!   coords : [ x₀ … x_{d−1} | x₀ … x_{d−1} | … ]   slot × dim, contiguous
//!   keys   : [ k₀ … k_{t−1} | k₀ … k_{t−1} | … ]   slot × t,   contiguous
//!   vertex / gen / live / core / attached_to / attached : dense Vecs
//! ```
//!
//! Slots are reused through a free list, so a steady-state workload
//! (sliding windows, bounded churn) performs **zero heap allocations per
//! update**: adding a point copies its coordinate and key rows into place,
//! deleting pushes the slot back on the free list. [`PointId`]s stay unique
//! forever by encoding `(generation << 32) | slot`: reusing a slot bumps
//! its generation, so a stale id of a deleted point can never alias a live
//! one (`get` rejects it, `require` panics).
//!
//! The encoding changes the *order* of ids (generation-major rather than
//! strict insertion order). Algorithm 2 only needs a total order on ids
//! that is consistent across buckets for its in-bucket core paths — any
//! injective map into `u64` qualifies — so Theorems 1–2 are unaffected
//! (machine-checked by [`super::invariants`]; insert-only streams keep the
//! old `0, 1, 2, …` ids exactly since every generation is 0).
//!
//! A core's attached non-core points live in an [`AttachedSet`]: an inline
//! array of up to [`ATTACH_INLINE`] ids that spills to a heap `FxHashSet`
//! only past that threshold, and drops the spill allocation again once it
//! empties.

use rustc_hash::FxHashSet;

use crate::ett::VertexId;
use crate::lsh::table::PointId;
use crate::lsh::BucketKey;

/// Attached non-cores stored inline before spilling to a heap set. With the
/// paper's parameters a non-core attaches to ≤ 1 core and cores adopt only
/// the orphans of their own buckets, so nearly all sets stay inline.
pub const ATTACH_INLINE: usize = 6;

const SLOT_BITS: u32 = 32;
const SLOT_MASK: u64 = (1u64 << SLOT_BITS) - 1;

#[inline]
fn raw_slot(p: PointId) -> usize {
    (p & SLOT_MASK) as usize
}

#[inline]
fn raw_gen(p: PointId) -> u32 {
    (p >> SLOT_BITS) as u32
}

/// Small-set of attached non-core points: inline up to [`ATTACH_INLINE`],
/// spilled to a `FxHashSet` beyond.
#[derive(Debug, Default)]
pub struct AttachedSet {
    len: u8,
    inline: [PointId; ATTACH_INLINE],
    spill: Option<Box<FxHashSet<PointId>>>,
}

impl AttachedSet {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn len(&self) -> usize {
        match &self.spill {
            Some(s) => s.len(),
            None => self.len as usize,
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True once the set has spilled to the heap (introspection for tests).
    pub fn is_spilled(&self) -> bool {
        self.spill.is_some()
    }

    pub fn contains(&self, p: PointId) -> bool {
        match &self.spill {
            Some(s) => s.contains(&p),
            None => self.inline[..self.len as usize].contains(&p),
        }
    }

    /// Insert `p` (must not already be present — a non-core attaches to at
    /// most one core, so duplicates cannot arise in Algorithm 2).
    pub fn insert(&mut self, p: PointId) {
        if let Some(s) = &mut self.spill {
            let fresh = s.insert(p);
            debug_assert!(fresh, "duplicate attachment of {p}");
            return;
        }
        let n = self.len as usize;
        debug_assert!(
            !self.inline[..n].contains(&p),
            "duplicate attachment of {p}"
        );
        if n < ATTACH_INLINE {
            self.inline[n] = p;
            self.len += 1;
        } else {
            // spill: move the inline elements plus `p` to the heap
            let mut s = Box::new(FxHashSet::default());
            s.extend(self.inline.iter().copied());
            s.insert(p);
            self.len = 0;
            self.spill = Some(s);
        }
    }

    /// Remove `p`; returns whether it was present. An emptied spill set
    /// reverts to inline mode, releasing the heap allocation.
    pub fn remove(&mut self, p: PointId) -> bool {
        match &mut self.spill {
            Some(s) => {
                let had = s.remove(&p);
                if s.is_empty() {
                    self.spill = None;
                }
                had
            }
            None => {
                let n = self.len as usize;
                match self.inline[..n].iter().position(|&q| q == p) {
                    Some(i) => {
                        self.inline[i] = self.inline[n - 1];
                        self.len -= 1;
                        true
                    }
                    None => false,
                }
            }
        }
    }

    /// Move every element into `out`, leaving the set empty and inline.
    pub fn drain_into(&mut self, out: &mut Vec<PointId>) {
        match self.spill.take() {
            Some(s) => out.extend(s.iter().copied()),
            None => {
                out.extend(self.inline[..self.len as usize].iter().copied());
                self.len = 0;
            }
        }
    }

    /// Clear without reporting contents (slot free).
    pub fn reset(&mut self) {
        self.len = 0;
        self.spill = None;
    }
}

/// The slab: parallel per-slot arrays plus a free list. See the module
/// docs for the layout.
pub struct PointArena {
    dim: usize,
    t: usize,
    coords: Vec<f32>,
    keys: Vec<BucketKey>,
    vertex: Vec<VertexId>,
    gen: Vec<u32>,
    live: Vec<bool>,
    core: Vec<bool>,
    attached_to: Vec<Option<PointId>>,
    attached: Vec<AttachedSet>,
    free: Vec<u32>,
    n_live: usize,
}

impl PointArena {
    pub fn new(dim: usize, t: usize) -> Self {
        assert!(dim > 0 && t > 0);
        PointArena {
            dim,
            t,
            coords: Vec::new(),
            keys: Vec::new(),
            vertex: Vec::new(),
            gen: Vec::new(),
            live: Vec::new(),
            core: Vec::new(),
            attached_to: Vec::new(),
            attached: Vec::new(),
            free: Vec::new(),
            n_live: 0,
        }
    }

    /// Live points.
    #[inline]
    pub fn len(&self) -> usize {
        self.n_live
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n_live == 0
    }

    /// Slots ever allocated (live + free-listed).
    pub fn capacity_slots(&self) -> usize {
        self.live.len()
    }

    /// Slots currently on the free list.
    pub fn free_slots(&self) -> usize {
        self.free.len()
    }

    /// Checked id → slot: `None` for out-of-range, dead, or stale
    /// (generation-mismatched) ids.
    #[inline]
    pub fn get(&self, p: PointId) -> Option<usize> {
        let slot = raw_slot(p);
        if slot < self.live.len() && self.live[slot] && self.gen[slot] == raw_gen(p) {
            Some(slot)
        } else {
            None
        }
    }

    /// Checked id → slot, panicking on unknown ids (the map-index behavior
    /// the query API had before the arena).
    #[inline]
    pub fn require(&self, p: PointId) -> usize {
        match self.get(p) {
            Some(s) => s,
            None => panic!("unknown point {p}"),
        }
    }

    /// Unchecked id → slot for ids read back out of live structures
    /// (bucket members, attachment lists): a mask in release, validated in
    /// debug.
    #[inline]
    pub fn slot_unchecked(&self, p: PointId) -> usize {
        debug_assert!(self.get(p).is_some(), "stale point id {p}");
        raw_slot(p)
    }

    #[inline]
    pub fn contains(&self, p: PointId) -> bool {
        self.get(p).is_some()
    }

    #[inline]
    pub fn id_of_slot(&self, slot: usize) -> PointId {
        debug_assert!(self.live[slot]);
        ((self.gen[slot] as u64) << SLOT_BITS) | slot as u64
    }

    /// Allocate a slot for a point, copying its coordinate and key rows in.
    /// Reuses a free slot when one exists (no allocation); otherwise grows
    /// every column by one row (amortized).
    pub fn alloc(&mut self, x: &[f32], keys: &[BucketKey], vertex: VertexId) -> PointId {
        debug_assert_eq!(x.len(), self.dim);
        debug_assert_eq!(keys.len(), self.t);
        let slot = match self.free.pop() {
            Some(s) => {
                let s = s as usize;
                self.coords[s * self.dim..(s + 1) * self.dim].copy_from_slice(x);
                self.keys[s * self.t..(s + 1) * self.t].copy_from_slice(keys);
                s
            }
            None => {
                let s = self.live.len();
                assert!(s < SLOT_MASK as usize, "arena slot space exhausted");
                self.coords.extend_from_slice(x);
                self.keys.extend_from_slice(keys);
                self.vertex.push(0);
                self.gen.push(0);
                self.live.push(false);
                self.core.push(false);
                self.attached_to.push(None);
                self.attached.push(AttachedSet::new());
                s
            }
        };
        debug_assert!(!self.live[slot]);
        debug_assert!(self.attached[slot].is_empty());
        self.live[slot] = true;
        self.core[slot] = false;
        self.attached_to[slot] = None;
        self.vertex[slot] = vertex;
        self.n_live += 1;
        self.id_of_slot(slot)
    }

    /// Release `p`'s slot to the free list, bumping its generation so the
    /// id can never be resolved again.
    pub fn free(&mut self, p: PointId) {
        let slot = self.require(p);
        debug_assert!(
            self.attached[slot].is_empty(),
            "freeing point {p} with live attachments"
        );
        self.live[slot] = false;
        self.core[slot] = false;
        self.attached_to[slot] = None;
        self.attached[slot].reset();
        self.gen[slot] = self.gen[slot].wrapping_add(1);
        self.free.push(slot as u32);
        self.n_live -= 1;
    }

    // -- per-slot accessors (slot from `get`/`require`/`slot_unchecked`) --

    #[inline]
    pub fn coords_row(&self, slot: usize) -> &[f32] {
        &self.coords[slot * self.dim..(slot + 1) * self.dim]
    }

    #[inline]
    pub fn key_row(&self, slot: usize) -> &[BucketKey] {
        &self.keys[slot * self.t..(slot + 1) * self.t]
    }

    /// Bucket key of hash function `i` — a 16-byte copy, so callers hold no
    /// borrow across table/forest mutations (this is what replaced the
    /// seven `keys.clone()` sites of the pre-arena update path).
    #[inline]
    pub fn key(&self, slot: usize, i: usize) -> BucketKey {
        self.keys[slot * self.t + i]
    }

    #[inline]
    pub fn vertex(&self, slot: usize) -> VertexId {
        self.vertex[slot]
    }

    #[inline]
    pub fn is_core(&self, slot: usize) -> bool {
        self.core[slot]
    }

    #[inline]
    pub fn set_core(&mut self, slot: usize, c: bool) {
        self.core[slot] = c;
    }

    #[inline]
    pub fn attached_to(&self, slot: usize) -> Option<PointId> {
        self.attached_to[slot]
    }

    #[inline]
    pub fn set_attached_to(&mut self, slot: usize, v: Option<PointId>) {
        self.attached_to[slot] = v;
    }

    #[inline]
    pub fn take_attached_to(&mut self, slot: usize) -> Option<PointId> {
        self.attached_to[slot].take()
    }

    #[inline]
    pub fn attached(&self, slot: usize) -> &AttachedSet {
        &self.attached[slot]
    }

    #[inline]
    pub fn attached_mut(&mut self, slot: usize) -> &mut AttachedSet {
        &mut self.attached[slot]
    }

    /// Live ids, unordered (slot order).
    pub fn ids(&self) -> impl Iterator<Item = PointId> + '_ {
        self.live
            .iter()
            .enumerate()
            .filter(|&(_, &l)| l)
            .map(|(s, _)| ((self.gen[s] as u64) << SLOT_BITS) | s as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_reuses_slots_with_fresh_ids() {
        let mut a = PointArena::new(2, 3);
        let p0 = a.alloc(&[0.0, 1.0], &[1, 2, 3], 10);
        let p1 = a.alloc(&[2.0, 3.0], &[4, 5, 6], 11);
        assert_eq!(a.len(), 2);
        assert_eq!(a.capacity_slots(), 2);
        assert_eq!(a.coords_row(a.require(p0)), &[0.0, 1.0]);
        assert_eq!(a.key_row(a.require(p1)), &[4, 5, 6]);
        a.free(p0);
        assert_eq!(a.len(), 1);
        assert!(!a.contains(p0));
        let p2 = a.alloc(&[7.0, 8.0], &[7, 8, 9], 12);
        // slot reused, id fresh
        assert_eq!(a.capacity_slots(), 2);
        assert_ne!(p2, p0);
        assert_eq!(a.require(p2), 0, "freed slot 0 must be reused");
        assert!(!a.contains(p0), "stale id must not resolve after reuse");
        assert_eq!(a.coords_row(a.require(p2)), &[7.0, 8.0]);
        assert_eq!(a.vertex(a.require(p2)), 12);
    }

    #[test]
    #[should_panic(expected = "unknown point")]
    fn require_rejects_stale_id() {
        let mut a = PointArena::new(1, 1);
        let p = a.alloc(&[0.0], &[0], 0);
        a.free(p);
        a.require(p);
    }

    #[test]
    fn ids_enumerates_live_points() {
        let mut a = PointArena::new(1, 1);
        let p0 = a.alloc(&[0.0], &[0], 0);
        let p1 = a.alloc(&[1.0], &[1], 1);
        let p2 = a.alloc(&[2.0], &[2], 2);
        a.free(p1);
        let mut ids: Vec<PointId> = a.ids().collect();
        ids.sort_unstable();
        let mut want = vec![p0, p2];
        want.sort_unstable();
        assert_eq!(ids, want);
    }

    #[test]
    fn attached_set_inline_then_spill_then_shrink() {
        let mut s = AttachedSet::new();
        assert!(s.is_empty() && !s.is_spilled());
        for p in 0..ATTACH_INLINE as u64 {
            s.insert(p);
        }
        assert_eq!(s.len(), ATTACH_INLINE);
        assert!(!s.is_spilled(), "must stay inline up to the threshold");
        s.insert(99);
        assert!(s.is_spilled(), "crossing the threshold spills");
        assert_eq!(s.len(), ATTACH_INLINE + 1);
        for p in 0..ATTACH_INLINE as u64 {
            assert!(s.contains(p));
            assert!(s.remove(p));
        }
        assert!(s.contains(99));
        assert!(s.remove(99));
        assert!(!s.is_spilled(), "emptied spill reverts to inline");
        assert!(s.is_empty());
        // usable again inline
        s.insert(7);
        assert!(s.contains(7) && !s.is_spilled());
    }

    #[test]
    fn attached_set_drain() {
        let mut s = AttachedSet::new();
        for p in [3u64, 1, 4, 11, 5] {
            s.insert(p);
        }
        let mut out = Vec::new();
        s.drain_into(&mut out);
        out.sort_unstable();
        assert_eq!(out, vec![1, 3, 4, 5, 11]);
        assert!(s.is_empty());
    }
}
