//! [`ReadRouter`] — staleness-bounded read fan-out over a replica set.
//!
//! Owns every follower built by `EngineBuilder::build_replicated` and
//! answers reads with [`crate::serve::SnapshotView`]s, spreading load by
//! [`ReadPreference`]. The staleness bound is measured in **leader
//! publishes** (the shared publish clock), never wall-clock: a returned
//! view lags the leader by at most `max_staleness` publish barriers,
//! enforced by synchronously catching the chosen replica up when it has
//! fallen past the bound (the pull model makes "catch up now" always
//! possible — everything published is already queued on the transport).

use crate::serve::{ClusterEngine, SnapshotView};

use super::engine::ReplicaEngine;

/// Which replica answers the next read.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadPreference {
    /// Strict rotation — even load, no lag awareness.
    RoundRobin,
    /// The replica with the fewest leader publishes outstanding (ties
    /// broken by index) — freshest answers under skewed apply rates.
    LeastLagged,
}

/// Staleness-bounded read router over the follower set. See the [module
/// docs](self).
pub struct ReadRouter {
    replicas: Vec<ReplicaEngine>,
    pref: ReadPreference,
    /// max leader publishes a served view may trail by (0 = always
    /// catch up before answering)
    max_staleness: u64,
    /// round-robin cursor
    next: usize,
}

impl ReadRouter {
    pub(crate) fn new(
        replicas: Vec<ReplicaEngine>,
        pref: ReadPreference,
        max_staleness: u64,
    ) -> Self {
        ReadRouter { replicas, pref, max_staleness, next: 0 }
    }

    /// Followers in the set.
    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    /// Drain every follower's shipped queue; returns total frames
    /// applied. Call between leader publishes to keep lag near zero, or
    /// let [`Self::read`] catch up lazily at the staleness bound.
    pub fn catch_up(&mut self) -> u64 {
        self.replicas.iter_mut().map(|r| r.catch_up()).sum()
    }

    /// Leader publishes outstanding per follower, by index.
    pub fn lags(&self) -> Vec<u64> {
        self.replicas.iter().map(|r| r.lag_publishes()).collect()
    }

    /// Serve one read: pick a replica by preference, catch it up if it
    /// trails the leader by more than the staleness bound, and return
    /// its view. Panics if the router was built with zero replicas.
    pub fn read(&mut self) -> SnapshotView {
        assert!(!self.replicas.is_empty(), "read on an empty replica set");
        let i = match self.pref {
            ReadPreference::RoundRobin => {
                let i = self.next % self.replicas.len();
                self.next = self.next.wrapping_add(1);
                i
            }
            ReadPreference::LeastLagged => self
                .replicas
                .iter()
                .enumerate()
                .min_by_key(|(i, r)| (r.lag_publishes(), *i))
                .map(|(i, _)| i)
                .unwrap_or(0),
        };
        if self.replicas[i].lag_publishes() > self.max_staleness {
            self.replicas[i].catch_up();
        }
        self.replicas[i].snapshot()
    }

    /// Direct access to one follower (diagnostics and tests).
    pub fn replica(&self, i: usize) -> &ReplicaEngine {
        &self.replicas[i]
    }

    /// Consume the router and promote follower `i` into a writable
    /// leader (draining its shipped tail); the other followers are
    /// dropped — their transports close, and the old leader's shipper
    /// (if it still runs) unsubscribes them on its next ship.
    pub fn promote(mut self, i: usize) -> Box<dyn ClusterEngine> {
        assert!(i < self.replicas.len(), "promote index out of range");
        let chosen = self.replicas.swap_remove(i);
        chosen.promote()
    }
}
