//! [`ReplicaEngine`] — the follower half of WAL log-shipping.
//!
//! A follower is a full clustering engine (same builder configuration,
//! same deterministic seed as the leader) that never accepts writes from
//! callers. It bootstraps by running the **leader's own recovery path**
//! (`serve::durable::recover_into`: checkpoint chain + WAL tail) against
//! the leader's persist directory, then applies shipped frames from its
//! transport: op frames replay through the same `upsert`/`remove`/`apply`
//! entry points, and every `Publish{seq, version}` marker triggers a
//! local publish whose [`SnapshotView`] is re-based to the leader's
//! `version` — version parity by construction, and (determinism of the
//! pipeline) bit-identical labels, neighborhoods and kNN answers at every
//! version the leader published.
//!
//! The pull model is synchronous: nothing happens between
//! [`ReplicaEngine::catch_up`] calls, which makes staleness a checkable
//! quantity (leader publish clock minus markers applied) rather than a
//! race, and keeps the follower free of background threads and wall-clock
//! reads.
//!
//! [`ReplicaEngine::promote`] flips the follower into a writable leader:
//! it drains every shipped frame, then hands back a `ClusterEngine` that
//! continues the leader's version numbering. Ops shipped after the last
//! marker (the un-published tail) survive promotion as pending writes.

use std::io;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::obs::Gauge;
use crate::persist::wal::{decode_frame, WalOp, WalRecord};
use crate::serve::durable::recover_into;
use crate::serve::{ClusterEngine, SnapshotView, Update};

use super::transport::FrameReceiver;

/// Read-only follower over a shipped WAL stream. See the [module
/// docs](self).
pub struct ReplicaEngine {
    inner: Box<dyn ClusterEngine>,
    rx: FrameReceiver,
    /// highest WAL sequence number applied (bootstrap floor, then the
    /// last shipped frame folded in)
    applied_seq: u64,
    /// external version = version_base + inner version (re-anchored at
    /// every applied `Publish` marker)
    version_base: u64,
    /// `Publish` markers applied since attach — the follower's side of
    /// the staleness clock
    applied_publishes: u64,
    /// the leader's publish count since attach (shared clock)
    leader_publishes: Arc<AtomicU64>,
    /// latest replica-published view (changes only at markers)
    view: SnapshotView,
}

impl ReplicaEngine {
    /// Bootstrap a follower: recover `inner` (a fresh engine built from
    /// the leader's configuration) from the leader's persist directory,
    /// exactly as the leader itself would recover. The returned engine's
    /// [`Self::floor`] is what the leader's shipper must subscribe past.
    pub fn bootstrap(
        mut inner: Box<dyn ClusterEngine>,
        dir: &Path,
        rx: FrameReceiver,
        leader_publishes: Arc<AtomicU64>,
    ) -> io::Result<ReplicaEngine> {
        let recovered = recover_into(dir, &mut inner)?;
        let mut view = inner.snapshot();
        view.rebase_version(recovered.version_base);
        Ok(ReplicaEngine {
            inner,
            rx,
            applied_seq: recovered.next_seq - 1,
            version_base: recovered.version_base,
            applied_publishes: 0,
            leader_publishes,
            view,
        })
    }

    /// Highest WAL sequence number the bootstrap (or shipping so far)
    /// has folded in — the shipper's subscription floor.
    pub fn floor(&self) -> u64 {
        self.applied_seq
    }

    /// Apply every shipped frame queued on the transport; returns how
    /// many frames were folded in. Op frames become pending writes;
    /// each `Publish` marker publishes locally and re-bases the view to
    /// the leader's version. A frame that fails to decode (CRC damage in
    /// transit) stops the drain — nothing past a damaged frame is
    /// trusted, mirroring the on-disk reader.
    pub fn catch_up(&mut self) -> u64 {
        let mut applied = 0u64;
        while let Some((seq, frame)) = self.rx.try_next() {
            if seq <= self.applied_seq {
                continue; // already covered by the bootstrap
            }
            let Some((rec, _)) = decode_frame(&frame) else {
                break;
            };
            self.apply_record(rec);
            self.applied_seq = seq;
            applied += 1;
        }
        if let Some(m) = self.inner.obs_registry() {
            m.set_gauge(Gauge::ReplicaLagPublishes, self.lag_publishes());
        }
        applied
    }

    fn apply_record(&mut self, rec: WalRecord) {
        match rec {
            WalRecord::Upsert { ext, coords, .. } => {
                self.inner.upsert(ext, &coords);
            }
            WalRecord::Remove { ext, .. } => self.inner.remove(ext),
            WalRecord::Apply { ops, .. } => {
                let batch: Vec<Update<'_>> = ops
                    .iter()
                    .map(|op| match op {
                        WalOp::Upsert { ext, coords } => Update::Upsert {
                            ext: *ext,
                            coords: coords.as_slice(),
                        },
                        WalOp::Remove { ext } => Update::Remove { ext: *ext },
                    })
                    .collect();
                self.inner.apply(&batch);
            }
            WalRecord::Publish { version, .. } => {
                let raw = self.inner.publish();
                // re-anchor so the local view carries the leader's
                // version numbering at this marker
                self.version_base = version.saturating_sub(raw.version());
                let mut view = raw;
                view.rebase_version(self.version_base);
                self.view = view;
                self.applied_publishes += 1;
            }
        }
    }

    /// The latest replica-published view. Carries the leader's version
    /// numbering; `pending_writes()` counts shipped ops applied after
    /// the last marker (visible only after the next marker).
    pub fn snapshot(&self) -> SnapshotView {
        let mut view = self.view.clone();
        view.set_pending(self.inner.pending_writes());
        view
    }

    /// Leader publishes this follower has not applied yet (0 = caught
    /// up). Counted in publish barriers since attach, never wall-clock.
    pub fn lag_publishes(&self) -> u64 {
        self.leader_publishes
            .load(Ordering::Relaxed)
            .saturating_sub(self.applied_publishes)
    }

    /// Data dimensionality (matches the leader).
    pub fn dim(&self) -> usize {
        self.inner.dim()
    }

    /// Drain the shipped tail, then flip into a writable leader that
    /// continues the leader's version numbering. Ops shipped after the
    /// last `Publish` marker become pending writes of the new leader.
    pub fn promote(mut self) -> Box<dyn ClusterEngine> {
        self.catch_up();
        Box::new(PromotedLeader {
            inner: self.inner,
            version_base: self.version_base,
        })
    }
}

/// A follower flipped writable: the wrapped backend plus the version
/// offset that keeps the old leader's numbering going.
struct PromotedLeader {
    inner: Box<dyn ClusterEngine>,
    version_base: u64,
}

impl ClusterEngine for PromotedLeader {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn upsert(&mut self, ext: u64, coords: &[f32]) {
        self.inner.upsert(ext, coords);
    }

    fn remove(&mut self, ext: u64) {
        self.inner.remove(ext);
    }

    fn apply(&mut self, batch: &[Update<'_>]) {
        self.inner.apply(batch);
    }

    fn contains(&self, ext: u64) -> bool {
        self.inner.contains(ext)
    }

    fn publish(&mut self) -> SnapshotView {
        let mut view = self.inner.publish();
        view.rebase_version(self.version_base);
        view
    }

    fn snapshot(&self) -> SnapshotView {
        let mut view = self.inner.snapshot();
        view.rebase_version(self.version_base);
        view
    }

    fn watch(&mut self) -> crate::serve::ClusterEvents {
        self.inner.watch()
    }

    fn pending_writes(&self) -> u64 {
        self.inner.pending_writes()
    }

    fn stats(&self) -> crate::serve::Stats {
        self.inner.stats()
    }

    fn metrics(&self) -> crate::serve::MetricsSnapshot {
        self.inner.metrics()
    }

    fn verify(&self) -> Result<(), String> {
        self.inner.verify()
    }

    fn obs_registry(&self) -> Option<Arc<crate::obs::Metrics>> {
        self.inner.obs_registry()
    }

    fn placement_blob(&self) -> Option<Vec<u8>> {
        self.inner.placement_blob()
    }

    fn placement_restore(&mut self, blob: &[u8]) {
        self.inner.placement_restore(blob);
    }

    fn install_wal_heal(&mut self, dir: &Path) {
        self.inner.install_wal_heal(dir);
    }

    fn finish(self: Box<Self>) -> crate::serve::ServeOutcome {
        let base = self.version_base;
        let mut out = self.inner.finish();
        out.snapshot.rebase_version(base);
        out
    }
}
