//! [`LogShipper`] — the leader half of WAL log-shipping.
//!
//! Lives inside the leader's `DurableEngine`, which calls
//! [`LogShipper::ship`] immediately after every publish fsync: the tail
//! it reads from disk (`persist::wal::read_frames_after`) is therefore
//! exactly the committed prefix, and followers can never observe a frame
//! the leader could lose in a crash. Each subscriber has its own shipped
//! floor — the highest WAL sequence number already sent to it — so a
//! freshly attached follower (bootstrapped from the checkpoint chain)
//! starts past what its bootstrap already covered, and the minimum floor
//! across subscribers ([`LogShipper::min_floor`]) is what the engine
//! feeds into WAL segment retention: sealed segments survive until the
//! slowest follower has their frames.
//!
//! A subscriber whose transport reports
//! [`TransportClosed`](super::transport::TransportClosed) is dropped on
//! the spot — a dead follower must not pin segment retention forever.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::persist::wal::read_frames_after;

use super::transport::Transport;

struct Subscriber {
    transport: Box<dyn Transport>,
    /// highest WAL sequence number already shipped to this follower
    floor: u64,
}

/// Leader-side log shipper: per-subscriber shipped floors over a shared
/// read of the on-disk WAL tail. See the [module docs](self).
pub struct LogShipper {
    subs: Vec<Subscriber>,
    /// leader publishes since the shipper was created — the reference
    /// clock for follower staleness (followers count the `Publish`
    /// markers they apply against this)
    publishes: Arc<AtomicU64>,
}

impl Default for LogShipper {
    fn default() -> Self {
        Self::new()
    }
}

impl LogShipper {
    pub fn new() -> Self {
        LogShipper { subs: Vec::new(), publishes: Arc::new(AtomicU64::new(0)) }
    }

    /// Attach a follower whose bootstrap already covers every record at
    /// or below `floor`; shipping starts with the first frame past it.
    pub fn subscribe(&mut self, transport: Box<dyn Transport>, floor: u64) {
        self.subs.push(Subscriber { transport, floor });
    }

    /// Live subscribers.
    pub fn subscribers(&self) -> usize {
        self.subs.len()
    }

    /// The shared leader-publish counter — cloned into each follower so
    /// it can compute its own lag without reaching into the leader.
    pub fn publish_clock(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.publishes)
    }

    /// Count one leader publish (called by the durable engine right
    /// after the publish fsync, before shipping its frames).
    pub fn note_publish(&self) {
        self.publishes.fetch_add(1, Ordering::Relaxed);
    }

    /// Ship every durable frame past each subscriber's floor, in log
    /// order; returns the total frames forwarded (summed over
    /// subscribers). Subscribers whose transport is closed are dropped.
    pub fn ship(&mut self, dir: &Path) -> std::io::Result<u64> {
        if self.subs.is_empty() {
            return Ok(0);
        }
        let read_floor = self.min_floor();
        let frames = read_frames_after(dir, read_floor)?;
        let mut shipped = 0u64;
        let mut kept = Vec::with_capacity(self.subs.len());
        for mut sub in self.subs.drain(..) {
            let mut alive = true;
            for (seq, frame) in &frames {
                if *seq <= sub.floor {
                    continue;
                }
                if sub.transport.send(*seq, frame).is_err() {
                    alive = false;
                    break;
                }
                sub.floor = *seq;
                shipped += 1;
            }
            if alive {
                kept.push(sub);
            }
        }
        self.subs = kept;
        Ok(shipped)
    }

    /// Slowest shipped floor across subscribers (`u64::MAX` with none) —
    /// the shipping side of the WAL segment retention floor.
    pub fn min_floor(&self) -> u64 {
        self.subs.iter().map(|s| s.floor).min().unwrap_or(u64::MAX)
    }
}
