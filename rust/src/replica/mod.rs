//! `replica` — WAL log-shipping read replicas with leader promotion.
//!
//! Replication reuses the durability pipeline end to end instead of
//! introducing a second state-transfer mechanism:
//!
//! ```text
//!   leader (DurableEngine)                      follower (ReplicaEngine)
//!   ──────────────────────                      ────────────────────────
//!   upsert/remove/apply ──► WAL frames          bootstrap:
//!   publish ──► fsync ──► LogShipper::ship        checkpoint chain +
//!        │                    │                    WAL tail (the leader's
//!        │              Transport (frames,         own recovery path)
//!        │               verbatim on-disk        then per shipped frame:
//!        │               bytes, CRC intact)        op     → replay write
//!        ▼                    ▼                    Publish→ local publish,
//!   checkpoint spill     FrameReceiver                      rebase to the
//!   + segment roll/retain                                   leader version
//! ```
//!
//! **What a follower serves.** Re-published [`SnapshotView`]s with the
//! leader's version numbering: a replica view at version `v` is
//! bit-identical to the leader's view at `v` (labels, ε-neighborhoods,
//! kNN) because both sides run the same deterministic pipeline over the
//! same op stream — the shipped frames are byte-for-byte the leader's
//! durable log. Views advance only at `Publish` markers; ops after the
//! last marker sit as pending writes, exactly like un-published writes
//! on the leader.
//!
//! **Staleness.** Measured in leader publish barriers via a shared
//! clock, never wall-clock. [`ReadRouter::read`] enforces the configured
//! bound by synchronously catching a lagging replica up before
//! answering; [`ReplicaEngine::catch_up`] is the only way follower state
//! advances (no background threads — lag is checkable, not racy).
//!
//! **Retention coupling.** The leader retains sealed WAL segments down
//! to `min(checkpoint floor, slowest shipped floor)`
//! ([`LogShipper::min_floor`]), so a lagging follower holds exactly the
//! history it still needs open, and nothing more.
//!
//! **Promotion.** [`ReadRouter::promote`] (or
//! [`ReplicaEngine::promote`]) drains the shipped tail and returns a
//! writable engine continuing the leader's version numbering — the
//! fail-over path when the leader process is gone. Ops the dead leader
//! accepted but never published are by contract not recovered (same
//! guarantee as its own crash recovery).
//!
//! Construct with `EngineBuilder::replicate(n)` +
//! `EngineBuilder::build_replicated` (requires `persist`); see the
//! quick-start in the crate docs.
//!
//! [`SnapshotView`]: crate::serve::SnapshotView

mod engine;
mod ship;
mod router;
pub mod transport;

pub use engine::ReplicaEngine;
pub use router::{ReadPreference, ReadRouter};
pub use ship::LogShipper;
pub use transport::{channel_pair, FrameReceiver, Transport, TransportClosed};
