//! Frame transport between a leader's [`super::LogShipper`] and a
//! follower's [`super::ReplicaEngine`].
//!
//! The unit of transfer is one **framed WAL record** — the exact
//! `[len][crc32][payload]` bytes the leader's crash-recovery reader
//! trusts on disk (`crate::persist::wal`). The shipper forwards those
//! bytes verbatim; the follower decodes them with
//! `persist::wal::decode_frame`. One wire format, one codec: anything a
//! follower applies is byte-for-byte what a local recovery would have
//! replayed, so the CRC travels end-to-end and a corrupted hop is
//! detected exactly like torn disk state.
//!
//! The only implementation today is the in-process channel pair
//! ([`channel_pair`]) used by `EngineBuilder::build_replicated` and the
//! differential tests. A network transport slots in behind the same
//! trait: the framing already carries lengths and checksums, so a TCP
//! stream of concatenated frames is self-delimiting.

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};

/// The peer of a transport is gone (follower dropped, socket closed).
/// The shipper responds by unsubscribing the peer so its floor stops
/// pinning WAL segment retention.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransportClosed;

impl std::fmt::Display for TransportClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("replication transport closed by peer")
    }
}

impl std::error::Error for TransportClosed {}

/// Leader-side frame sink. `seq` duplicates the sequence number already
/// inside the frame so the receiver can track its floor without decoding
/// twice.
pub trait Transport: Send {
    /// Queue one framed WAL record for delivery, in log order.
    fn send(&mut self, seq: u64, frame: &[u8]) -> Result<(), TransportClosed>;
}

/// In-process [`Transport`]: an unbounded mpsc sender. Unbounded is the
/// right shape for the synchronous pull model — the leader ships inside
/// its publish and must never block on a follower that has not drained
/// yet; memory is bounded by how far the slowest follower lags.
struct ChannelTransport {
    tx: Sender<(u64, Vec<u8>)>,
}

impl Transport for ChannelTransport {
    fn send(&mut self, seq: u64, frame: &[u8]) -> Result<(), TransportClosed> {
        self.tx.send((seq, frame.to_vec())).map_err(|_| TransportClosed)
    }
}

/// Follower-side end of an in-process transport: non-blocking drain of
/// whatever the leader has shipped so far.
pub struct FrameReceiver {
    rx: Receiver<(u64, Vec<u8>)>,
}

impl FrameReceiver {
    /// Next queued `(seq, frame)` if one is ready. `None` means the
    /// queue is empty *or* the leader is gone — the follower cannot tell
    /// the difference and does not need to: both mean "nothing more to
    /// apply right now".
    pub fn try_next(&mut self) -> Option<(u64, Vec<u8>)> {
        match self.rx.try_recv() {
            Ok(item) => Some(item),
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => None,
        }
    }
}

/// A connected in-process transport pair: the sender side goes to
/// `LogShipper::subscribe`, the receiver side to `ReplicaEngine`.
pub fn channel_pair() -> (Box<dyn Transport>, FrameReceiver) {
    let (tx, rx) = channel();
    (Box::new(ChannelTransport { tx }), FrameReceiver { rx })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pair_delivers_in_order_and_detects_drop() {
        let (mut tx, mut rx) = channel_pair();
        tx.send(1, b"abc").unwrap();
        tx.send(2, b"defg").unwrap();
        assert_eq!(rx.try_next(), Some((1, b"abc".to_vec())));
        assert_eq!(rx.try_next(), Some((2, b"defg".to_vec())));
        assert_eq!(rx.try_next(), None);
        drop(rx);
        assert_eq!(tx.send(3, b"x"), Err(TransportClosed));
    }
}
