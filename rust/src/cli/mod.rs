//! Minimal CLI substrate (clap is unavailable offline): subcommand + flag
//! parsing with typed accessors, `--help` generation, and the command
//! implementations for the `dyn-dbscan` binary.

pub mod commands;

use std::collections::BTreeMap;

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("unknown flag --{0}")]
    UnknownFlag(String),
    #[error("flag --{0} expects a value")]
    MissingValue(String),
    #[error("invalid value for --{0}: {1}")]
    BadValue(String, String),
}

/// Parsed arguments: positional subcommand + `--key value` flags
/// (`--flag` with no value = "true").
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                // --key=value | --key value | --switch
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(key.into(), v.clone())),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(key.into(), v.clone())),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, CliError> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(key.into(), v.clone())),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }
}

pub const USAGE: &str = "\
dyn-dbscan — Dynamic DBSCAN with Euler Tour Sequences (AISTATS 2025)

USAGE:
    dyn-dbscan <COMMAND> [FLAGS]

COMMANDS:
    table2     Reproduce Table 2 (time/ARI/NMI per dataset)
                 --datasets letter,mnist,...   (default: all six)
                 --scale 0.05  --runs 3  --engine native|xla
    fig2       Reproduce Figure 2, panel a|b|c
                 --panel a  --scale 0.05  --seed 42  --exact
    stream     Stream a dataset through the serve facade, printing
               per-snapshot reports (one engine API for every backend)
                 --dataset blobs --scale 0.05 --batch 1000
                 --order random|clustered --engine native|xla
                 --snapshot-every 5 --window N (sliding-window deletes)
                 --shards N (N > 1: sharded backend with incremental
                 cross-shard stitching; otherwise the single backend)
                 --conn leveled|repair|paper (connectivity ablation;
                 flat modes force full-rebuild publishing)
                 --stitch delta|full-rebuild (delta: O(Δ) publishes,
                 the default; full-rebuild: legacy O(n log n))
                 --metrics-every N (every N batches, print the live
                 metrics registry as Prometheus text exposition:
                 latency quantiles, per-stage publish/update spans,
                 structural gauges)
                 --persist DIR (durable engine: op-log WAL + periodic
                 checkpoint in DIR; a rerun recovers the persisted
                 state before streaming)
                 --replicas N (with --persist: N WAL-shipped read
                 replicas bootstrapped from the checkpoint chain; the
                 run reports shipped frames and version parity)
    query      Load a dataset, publish one snapshot, then answer point
               queries through the snapshot-pinned ε-cell index AND the
               brute-force scan oracle (timed, cross-checked identical)
                 --eps X1,X2,...   ε-neighborhood probe at that point
                 --knn K --at X1,X2,...   K nearest neighbors
                 --dataset blobs --scale 0.05 --seed 42
                 --k/--t N --radius R (DBSCAN params; R is the ε radius)
                 --no-index (force the scan fallback everywhere)
    verify     Run the Theorem-2 invariant checker on a random workload
               driven through the serve facade
                 --ops 2000 --seed 7
    info       List compiled AOT artifacts and their shapes

ENVIRONMENT:
    FULL=1                paper-size datasets (default: SCALE=0.05)
    SCALE=<f>             dataset scale factor
    RUNS=<n>              experiment repetitions
    DYN_DBSCAN_ARTIFACTS  artifacts directory (default: ./artifacts)
";

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = Args::parse(&argv(&[
            "table2",
            "--scale",
            "0.1",
            "--engine=xla",
            "--verbose",
        ]))
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("table2"));
        assert_eq!(a.get_f64("scale", 1.0).unwrap(), 0.1);
        assert_eq!(a.get("engine"), Some("xla"));
        assert!(a.get_bool("verbose"));
        assert!(!a.get_bool("quiet"));
    }

    #[test]
    fn defaults_and_errors() {
        let a = Args::parse(&argv(&["fig2", "--scale", "abc"])).unwrap();
        assert_eq!(a.get_usize("runs", 3).unwrap(), 3);
        assert!(a.get_f64("scale", 1.0).is_err());
    }

    #[test]
    fn positional_args() {
        let a = Args::parse(&argv(&["fig2", "b", "--seed", "9"])).unwrap();
        assert_eq!(a.positional, vec!["b".to_string()]);
        assert_eq!(a.get_u64("seed", 0).unwrap(), 9);
    }
}
