//! CLI command implementations.

use anyhow::{anyhow, Result};

use crate::bench_harness::export_json;
use crate::coordinator::driver::to_stream_ops;
use crate::data::stream::{self, Order};
use crate::data::synth::{load, PaperDataset};
use crate::dbscan::DbscanConfig;
use crate::experiments::fig2::{run_fig2, Panel};
use crate::experiments::table2::run_table2;
use crate::experiments::{env_runs, env_scale, PAPER_BATCH, PAPER_EPS, PAPER_K, PAPER_T};
use crate::runtime::Runtime;
use crate::serve::driver::{final_quality, run_stream_with, summarize};
use crate::serve::{
    Backend, ClusterEngine, ConnKind, EngineBuilder, EngineKind, StitchMode,
};
use crate::util::rng::Rng;

use super::Args;

pub fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("table2") => cmd_table2(args),
        Some("fig2") => cmd_fig2(args),
        Some("stream") => cmd_stream(args),
        Some("query") => cmd_query(args),
        Some("verify") => cmd_verify(args),
        Some("info") => cmd_info(args),
        Some("help") | None => {
            print!("{}", super::USAGE);
            Ok(())
        }
        Some(other) => Err(anyhow!("unknown command '{other}'\n\n{}", super::USAGE)),
    }
}

fn engine_kind(args: &Args) -> Result<EngineKind> {
    let name = args.get("engine").unwrap_or("native");
    EngineKind::from_name(name).ok_or_else(|| anyhow!("unknown engine '{name}'"))
}

fn cmd_table2(args: &Args) -> Result<()> {
    let scale = args.get_f64("scale", env_scale())?;
    let runs = args.get_usize("runs", env_runs())?;
    let engine = engine_kind(args)?;
    let datasets: Vec<PaperDataset> = match args.get("datasets") {
        None => PaperDataset::ALL.to_vec(),
        Some(list) => list
            .split(',')
            .map(|s| {
                PaperDataset::from_name(s.trim())
                    .ok_or_else(|| anyhow!("unknown dataset '{s}'"))
            })
            .collect::<Result<_>>()?,
    };
    let (table, _) = run_table2(&datasets, scale, runs, engine)?;
    table.print();
    export_json(&table.to_json());
    Ok(())
}

fn cmd_fig2(args: &Args) -> Result<()> {
    let panel_name = args
        .get("panel")
        .map(|s| s.to_string())
        .or_else(|| args.positional.first().cloned())
        .unwrap_or_else(|| "a".into());
    let panel = Panel::from_name(&panel_name)
        .ok_or_else(|| anyhow!("unknown panel '{panel_name}' (a|b|c)"))?;
    let scale = args.get_f64("scale", env_scale())?;
    let seed = args.get_u64("seed", 42)?;
    let exact = args.get_bool("exact");
    let series = run_fig2(panel, scale, seed, exact)?;
    series.print();
    export_json(&series.to_json());
    Ok(())
}

fn cmd_stream(args: &Args) -> Result<()> {
    let name = args.get("dataset").unwrap_or("blobs");
    let which = PaperDataset::from_name(name)
        .ok_or_else(|| anyhow!("unknown dataset '{name}'"))?;
    let scale = args.get_f64("scale", env_scale())?;
    let seed = args.get_u64("seed", 42)?;
    let batch = args.get_usize("batch", PAPER_BATCH)?;
    let snapshot = args.get_usize("snapshot-every", 5)?;
    let metrics_every = args.get_usize("metrics-every", 0)?;
    let window = args.get_usize("window", 0)?;
    let order = match args.get("order").unwrap_or("random") {
        "random" => Order::Random,
        "clustered" => Order::ClusterByCluster,
        o => return Err(anyhow!("unknown order '{o}'")),
    };
    let kind = engine_kind(args)?;
    let shards = args.get_usize("shards", 1)?;
    let replicas = args.get_usize("replicas", 0)?;
    let conn = {
        let name = args.get("conn").unwrap_or("leveled");
        ConnKind::from_name(name)
            .ok_or_else(|| anyhow!("unknown conn '{name}' (leveled|repair|paper)"))?
    };
    let stitch = match args.get("stitch") {
        None => None,
        Some("delta") => Some(StitchMode::Delta),
        Some("full-rebuild") | Some("full") => Some(StitchMode::FullRebuild),
        Some(s) => {
            return Err(anyhow!("unknown stitch mode '{s}' (delta|full-rebuild)"))
        }
    };

    let ds = load(which, scale, seed);
    let cfg = DbscanConfig {
        k: args.get_usize("k", PAPER_K)?,
        t: args.get_usize("t", PAPER_T)?,
        eps: args.get_f64("eps", PAPER_EPS as f64)? as f32,
        dim: ds.dim,
        eager_attach: args.get_bool("eager-attach"),
    };
    let batches = if window > 0 {
        stream::sliding_window_stream(&ds, order, batch, window, seed)
    } else {
        stream::insert_stream(&ds, order, batch, seed)
    };
    let ops = to_stream_ops(&ds, &batches);

    if shards > 1 && kind != EngineKind::Native {
        eprintln!(
            "[stream] note: --engine {kind:?} applies to the single-backend \
             hash stage; sharded workers hash natively"
        );
    }
    let mut builder = EngineBuilder::from_config(cfg)
        .seed(seed)
        .hashing(kind)
        .conn(conn)
        .backend(if shards > 1 { Backend::Sharded(shards) } else { Backend::Single });
    if let Some(s) = stitch {
        builder = builder.stitch(s);
    }
    if let Some(dir) = args.get("persist") {
        builder = builder.persist(dir);
        println!("persisting into {dir} (WAL + periodic checkpoint; recovers on reopen)");
    }
    println!(
        "streaming {} (n={}, d={}) in {} batches; backend={} conn={conn:?} \
         stitch={:?} hashing={kind:?}",
        ds.name,
        ds.n(),
        ds.dim,
        ops.len(),
        if shards > 1 { format!("sharded({shards})") } else { "single".into() },
        builder.effective_stitch(),
    );
    let (engine, mut router) = if replicas > 0 {
        if args.get("persist").is_none() {
            return Err(anyhow!(
                "--replicas needs --persist DIR: replicas bootstrap from the \
                 checkpoint chain and ship the on-disk WAL"
            ));
        }
        let (leader, router) =
            builder.replicate(replicas).build_replicated()?;
        println!(
            "replicating to {replicas} read replica(s) \
             (WAL log-shipping at every publish fsync)"
        );
        (leader, Some(router))
    } else {
        (builder.build()?, None)
    };
    let labels = ds.labels.clone();
    let truth = move |e: u64| labels[e as usize];
    let mut emit = |text: &str| print!("{text}");
    let out = run_stream_with(
        engine,
        ops,
        snapshot,
        Some(&truth),
        metrics_every,
        &mut emit,
    )?;
    for r in &out.reports {
        println!("{}", summarize(r));
    }
    let (ari, nmi) = final_quality(&ds, &out);
    let stats = &out.outcome.stats;
    println!(
        "\nfinal: live={} ARI={ari:.3} NMI={nmi:.3} wall={:.2}s ({:.0} updates/s)",
        out.final_labels.len(),
        out.total_wall_s,
        out.updates_per_s()
    );
    if stats.shards > 1 {
        println!(
            "sharding: {} primary + {} ghost inserts (ghost ratio {:.2}), {} deletes",
            stats.inserts,
            stats.ghost_inserts,
            stats.ghost_ratio(),
            stats.deletes
        );
    }
    println!("add     latency: {}", stats.add_latency.summary());
    println!("delete  latency: {}", stats.delete_latency.summary());
    println!("publish latency: {}", stats.publish_latency.summary());
    if let Some(router) = router.as_mut() {
        // the final publish shipped its frames before the leader shut
        // down; drain them and show version parity
        let applied = router.catch_up();
        let replica_view = router.read();
        println!(
            "replication: {} replica(s) applied {applied} shipped frames; \
             replica version {} vs leader {}",
            router.len(),
            replica_view.version(),
            out.outcome.snapshot.version(),
        );
    }
    Ok(())
}

/// Parse a `"X1,X2,..."` flag value into a `dim`-length coordinate row.
/// Comma-separated form keeps negative coordinates unambiguous to the
/// flag parser (a bare `-1.5` token would read as a flag).
fn parse_point(s: &str, dim: usize) -> Result<Vec<f32>> {
    let p: Vec<f32> = s
        .split(',')
        .map(|t| {
            t.trim()
                .parse::<f32>()
                .map_err(|_| anyhow!("bad coordinate '{t}' in '{s}'"))
        })
        .collect::<Result<_>>()?;
    if p.len() != dim {
        return Err(anyhow!(
            "probe point has {} coordinates, dataset dim is {dim}",
            p.len()
        ));
    }
    Ok(p)
}

/// Point queries against one published snapshot: ε-neighborhood
/// (`--eps X1,X2,...`) and/or k-nearest (`--knn K --at X1,X2,...`),
/// answered through the snapshot-pinned ε-cell index *and* the
/// brute-force scan oracle — timed separately, cross-checked for
/// bit-identical results.
fn cmd_query(args: &Args) -> Result<()> {
    let name = args.get("dataset").unwrap_or("blobs");
    let which = PaperDataset::from_name(name)
        .ok_or_else(|| anyhow!("unknown dataset '{name}'"))?;
    let scale = args.get_f64("scale", env_scale())?;
    let seed = args.get_u64("seed", 42)?;
    let ds = load(which, scale, seed);
    let cfg = DbscanConfig {
        k: args.get_usize("k", PAPER_K)?,
        t: args.get_usize("t", PAPER_T)?,
        eps: args.get_f64("radius", PAPER_EPS as f64)? as f32,
        dim: ds.dim,
        eager_attach: false,
    };
    let eps_probe = args.get("eps").map(|s| parse_point(s, ds.dim)).transpose()?;
    let knn_k = args.get_usize("knn", 0)?;
    let at = args.get("at").map(|s| parse_point(s, ds.dim)).transpose()?;
    if eps_probe.is_none() && knn_k == 0 {
        return Err(anyhow!(
            "nothing to query: pass --eps X1,X2,... and/or --knn K --at X1,X2,..."
        ));
    }
    let mut builder = EngineBuilder::from_config(cfg).seed(seed);
    if args.get_bool("no-index") {
        builder = builder.spatial_index(false);
    }
    let mut eng = builder.build()?;
    for i in 0..ds.n() {
        eng.upsert(i as u64, ds.point(i));
    }
    let t0 = std::time::Instant::now();
    let view = eng.publish();
    println!(
        "{}: n={} dim={} published v{} in {:.1} ms — {} (ε={})",
        ds.name,
        ds.n(),
        ds.dim,
        view.version(),
        t0.elapsed().as_secs_f64() * 1e3,
        if view.has_spatial_index() {
            "ε-cell index pinned to the snapshot"
        } else {
            "scan fallback (index off)"
        },
        view.eps(),
    );
    if let Some(p) = &eps_probe {
        let t0 = std::time::Instant::now();
        let hits = view.epsilon_neighbors(p);
        let idx_us = t0.elapsed().as_secs_f64() * 1e6;
        let t0 = std::time::Instant::now();
        let oracle = view.epsilon_neighbors_scan(p);
        let scan_us = t0.elapsed().as_secs_f64() * 1e6;
        if hits != oracle {
            return Err(anyhow!(
                "indexed ε-query diverged from the scan oracle at {p:?}"
            ));
        }
        let shown = hits.len().min(16);
        println!(
            "ε-neighborhood at {:?}: {} points in {idx_us:.0} µs \
             (scan {scan_us:.0} µs, identical): {:?}{}",
            p,
            hits.len(),
            &hits[..shown],
            if hits.len() > shown { " …" } else { "" },
        );
    }
    if knn_k > 0 {
        let p = at.as_ref().or(eps_probe.as_ref()).ok_or_else(|| {
            anyhow!("--knn needs a probe point: --at X1,X2,...")
        })?;
        let t0 = std::time::Instant::now();
        let hits = view.k_nearest(p, knn_k);
        let idx_us = t0.elapsed().as_secs_f64() * 1e6;
        let t0 = std::time::Instant::now();
        let oracle = view.k_nearest_scan(p, knn_k);
        let scan_us = t0.elapsed().as_secs_f64() * 1e6;
        if hits != oracle {
            return Err(anyhow!("indexed kNN diverged from the scan oracle at {p:?}"));
        }
        println!(
            "{} nearest to {p:?} in {idx_us:.0} µs (scan {scan_us:.0} µs, identical):",
            hits.len()
        );
        for (ext, d) in &hits {
            println!(
                "  ext {ext:<10} dist {d:.4}  label {}",
                view.label(*ext).unwrap_or(-1)
            );
        }
    }
    let _ = eng.finish();
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<()> {
    let ops = args.get_usize("ops", 2000)?;
    let seed = args.get_u64("seed", 7)?;
    let mut rng = Rng::new(seed);
    let mut eng = EngineBuilder::new(3).k(4).t(6).eps(0.5).seed(seed).build()?;
    let mut live: Vec<u64> = Vec::new();
    let mut next_ext = 0u64;
    let mut checked = 0;
    for op in 0..ops {
        if live.is_empty() || rng.coin(0.7) {
            let c = rng.below(3) as f64 * 3.0;
            let p: Vec<f32> =
                (0..3).map(|_| (c + rng.uniform(-0.5, 0.5)) as f32).collect();
            eng.upsert(next_ext, &p);
            live.push(next_ext);
            next_ext += 1;
        } else {
            let i = rng.below_usize(live.len());
            eng.remove(live.swap_remove(i));
        }
        // full invariant check is O(n²); sample it
        if op % 50 == 0 {
            eng.verify()
                .map_err(|e| anyhow!("invariant violated at op {op}: {e}"))?;
            checked += 1;
        }
    }
    eng.verify().map_err(|e| anyhow!("final invariant violated: {e}"))?;
    let view = eng.publish();
    println!(
        "verify OK: {ops} ops, {} live points, {} cores, {} full checks",
        view.live_points(),
        view.core_points(),
        checked + 1
    );
    Ok(())
}

fn cmd_info(_args: &Args) -> Result<()> {
    let dir = Runtime::default_dir();
    if !Runtime::available(&dir) {
        println!("no artifacts at {dir:?} — run `make artifacts`");
        return Ok(());
    }
    let rt = Runtime::new(&dir)?;
    let mut names: Vec<&String> = rt.artifacts.keys().collect();
    names.sort();
    println!("artifacts at {dir:?}:");
    for n in names {
        let a = &rt.artifacts[n];
        let ins: Vec<String> = a
            .inputs
            .iter()
            .map(|i| format!("{}{:?}", i.dtype, i.shape))
            .collect();
        println!(
            "  {:<28} {:<8} {} -> {}{:?}",
            a.name,
            a.kind,
            ins.join(", "),
            a.output.dtype,
            a.output.shape
        );
    }
    Ok(())
}
