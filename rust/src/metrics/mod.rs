//! Clustering-quality metrics used in the paper's evaluation: Adjusted Rand
//! Index and Normalized Mutual Information (arithmetic normalization, the
//! scikit-learn default the paper reports).
//!
//! Noise points labeled `-1` are treated as an ordinary label value —
//! matching `sklearn.metrics.adjusted_rand_score` /
//! `normalized_mutual_info_score` behaviour on DBSCAN outputs.

mod ari;
mod contingency;
mod nmi;

pub use ari::adjusted_rand_index;
pub use contingency::Contingency;
pub use nmi::normalized_mutual_info;

/// Convenience: both metrics at once (shares the contingency table).
pub fn ari_nmi(truth: &[i64], pred: &[i64]) -> (f64, f64) {
    let c = Contingency::build(truth, pred);
    (ari::ari_from_contingency(&c), nmi::nmi_from_contingency(&c))
}
