//! Normalized Mutual Information with arithmetic-mean normalization
//! (`sklearn.metrics.normalized_mutual_info_score` default).

use super::contingency::Contingency;

fn entropy(marginals: &rustc_hash::FxHashMap<i64, u64>, n: f64) -> f64 {
    marginals
        .values()
        .map(|&c| {
            let p = c as f64 / n;
            if p > 0.0 {
                -p * p.ln()
            } else {
                0.0
            }
        })
        .sum()
}

pub fn nmi_from_contingency(c: &Contingency) -> f64 {
    let n = c.n as f64;
    if c.n == 0 {
        return 1.0;
    }
    let hu = entropy(&c.row_sums, n);
    let hv = entropy(&c.col_sums, n);
    // MI = sum_ij p_ij ln(p_ij / (p_i p_j))
    let mut mi = 0.0;
    for (&(i, j), &nij) in &c.cells {
        let pij = nij as f64 / n;
        let pi = c.row_sums[&i] as f64 / n;
        let pj = c.col_sums[&j] as f64 / n;
        if pij > 0.0 {
            mi += pij * (pij / (pi * pj)).ln();
        }
    }
    let denom = 0.5 * (hu + hv);
    if denom <= 1e-15 {
        // both labelings constant: by sklearn convention NMI = 1 if identical
        // partitions else 0; constant vs constant is identical ⇒ 1.
        return 1.0;
    }
    (mi / denom).clamp(0.0, 1.0)
}

/// NMI between a ground-truth labeling and a predicted labeling.
pub fn normalized_mutual_info(truth: &[i64], pred: &[i64]) -> f64 {
    nmi_from_contingency(&Contingency::build(truth, pred))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_agreement() {
        let t = [0i64, 0, 1, 1, 2, 2];
        let p = [5i64, 5, 7, 7, 9, 9];
        assert!((normalized_mutual_info(&t, &p) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sklearn_fixture() {
        // normalized_mutual_info_score([0,0,1,1],[0,0,1,2]) = 0.8 exactly
        // (MI = ln2, H(U) = ln2, H(V) = 1.5·ln2, arithmetic mean = 1.25·ln2)
        let t = [0i64, 0, 1, 1];
        let p = [0i64, 0, 1, 2];
        let got = normalized_mutual_info(&t, &p);
        assert!((got - 0.8).abs() < 1e-12, "got {got}");
    }

    #[test]
    fn independent_labelings_zero() {
        // [0,0,1,1] vs [0,1,0,1]: MI = 0
        let t = [0i64, 0, 1, 1];
        let p = [0i64, 1, 0, 1];
        assert!(normalized_mutual_info(&t, &p).abs() < 1e-12);
    }

    #[test]
    fn constant_labelings() {
        let t = [0i64; 4];
        let p = [7i64; 4];
        assert_eq!(normalized_mutual_info(&t, &p), 1.0);
    }

    #[test]
    fn bounded_in_unit_interval() {
        use crate::util::proptest::{run_prop, Gen};
        run_prop("nmi in [0,1]", 100, |g: &mut Gen| {
            let n = g.usize_in(1..=40);
            let t: Vec<i64> = (0..n).map(|_| g.usize_in(0..=4) as i64 - 1).collect();
            let p: Vec<i64> = (0..n).map(|_| g.usize_in(0..=4) as i64 - 1).collect();
            let v = normalized_mutual_info(&t, &p);
            assert!((0.0..=1.0).contains(&v), "nmi {v} out of range");
        });
    }
}
