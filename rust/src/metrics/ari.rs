//! Adjusted Rand Index (Hubert & Arabie 1985).
//!
//! `ARI = (Index − E[Index]) / (MaxIndex − E[Index])` over pair counts;
//! 1.0 for identical partitions (up to relabeling), ~0 for independent ones.

use super::contingency::{comb2, Contingency};

pub fn ari_from_contingency(c: &Contingency) -> f64 {
    let sum_cells: f64 = c.cells.values().map(|&v| comb2(v)).sum();
    let sum_rows: f64 = c.row_sums.values().map(|&v| comb2(v)).sum();
    let sum_cols: f64 = c.col_sums.values().map(|&v| comb2(v)).sum();
    let total = comb2(c.n as u64);
    if total == 0.0 {
        return 1.0; // degenerate: <2 points
    }
    let expected = sum_rows * sum_cols / total;
    let max_index = 0.5 * (sum_rows + sum_cols);
    if (max_index - expected).abs() < 1e-12 {
        // both partitions are all-singletons or a single cluster
        return 1.0;
    }
    (sum_cells - expected) / (max_index - expected)
}

/// ARI between a ground-truth labeling and a predicted labeling.
pub fn adjusted_rand_index(truth: &[i64], pred: &[i64]) -> f64 {
    ari_from_contingency(&Contingency::build(truth, pred))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_agreement() {
        let t = [0i64, 0, 1, 1, 2, 2];
        let p = [5i64, 5, 7, 7, 9, 9]; // same partition, renamed
        assert!((adjusted_rand_index(&t, &p) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sklearn_fixture() {
        // sklearn.metrics.adjusted_rand_score([0,0,1,1],[0,0,1,2]) = 0.5714285714285715
        let t = [0i64, 0, 1, 1];
        let p = [0i64, 0, 1, 2];
        assert!((adjusted_rand_index(&t, &p) - 0.571_428_571_428_571_5).abs() < 1e-12);
    }

    #[test]
    fn sklearn_fixture_2() {
        // adjusted_rand_score([0,0,1,2],[0,0,1,1]) is symmetric = 0.57142857...
        let t = [0i64, 0, 1, 2];
        let p = [0i64, 0, 1, 1];
        assert!((adjusted_rand_index(&t, &p) - 0.571_428_571_428_571_5).abs() < 1e-12);
    }

    #[test]
    fn independent_is_near_zero_can_be_negative() {
        // adjusted_rand_score([0,0,1,1],[0,1,0,1]) = -0.5
        let t = [0i64, 0, 1, 1];
        let p = [0i64, 1, 0, 1];
        assert!((adjusted_rand_index(&t, &p) + 0.5).abs() < 1e-12);
    }

    #[test]
    fn degenerate_cases() {
        assert_eq!(adjusted_rand_index(&[0], &[3]), 1.0);
        // all singletons vs all singletons
        let t = [0i64, 1, 2, 3];
        let p = [9i64, 8, 7, 6];
        assert_eq!(adjusted_rand_index(&t, &p), 1.0);
        // one-cluster vs one-cluster
        let t = [0i64; 5];
        let p = [1i64; 5];
        assert_eq!(adjusted_rand_index(&t, &p), 1.0);
    }

    #[test]
    fn noise_as_label() {
        // -1 labels participate as a normal cluster, like sklearn
        let t = [0i64, 0, 1, 1];
        let p = [-1i64, -1, 1, 1];
        assert!((adjusted_rand_index(&t, &p) - 1.0).abs() < 1e-12);
    }
}
