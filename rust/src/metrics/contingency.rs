//! Contingency table between two labelings (sparse, hashmap-backed).

use rustc_hash::FxHashMap;

/// Sparse contingency table: `cells[(i,j)]` = #points with truth-class i and
/// predicted-cluster j, plus the marginals.
pub struct Contingency {
    pub n: usize,
    pub cells: FxHashMap<(i64, i64), u64>,
    pub row_sums: FxHashMap<i64, u64>,
    pub col_sums: FxHashMap<i64, u64>,
}

impl Contingency {
    pub fn build(truth: &[i64], pred: &[i64]) -> Self {
        assert_eq!(
            truth.len(),
            pred.len(),
            "labelings must cover the same points"
        );
        let mut cells: FxHashMap<(i64, i64), u64> = FxHashMap::default();
        let mut row_sums: FxHashMap<i64, u64> = FxHashMap::default();
        let mut col_sums: FxHashMap<i64, u64> = FxHashMap::default();
        for (&a, &b) in truth.iter().zip(pred.iter()) {
            *cells.entry((a, b)).or_insert(0) += 1;
            *row_sums.entry(a).or_insert(0) += 1;
            *col_sums.entry(b).or_insert(0) += 1;
        }
        Contingency { n: truth.len(), cells, row_sums, col_sums }
    }
}

/// n choose 2 as f64.
#[inline]
pub fn comb2(n: u64) -> f64 {
    n as f64 * (n as f64 - 1.0) / 2.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_marginals() {
        let t = [0i64, 0, 1, 1, 1];
        let p = [0i64, 1, 1, 1, 2];
        let c = Contingency::build(&t, &p);
        assert_eq!(c.n, 5);
        assert_eq!(c.cells[&(1, 1)], 2);
        assert_eq!(c.row_sums[&1], 3);
        assert_eq!(c.col_sums[&1], 3);
    }

    #[test]
    fn comb2_basics() {
        assert_eq!(comb2(0), 0.0);
        assert_eq!(comb2(1), 0.0);
        assert_eq!(comb2(4), 6.0);
    }
}
