//! Treap-backed sequence: the Henzinger–King "balanced binary tree" Euler
//! tour realization.
//!
//! Elements are treap nodes ordered purely by position; each node keeps a
//! parent pointer so any element can locate its sequence root (= canonical
//! sequence id) in `O(log n)` expected, and a subtree size so sequence
//! lengths are `O(1)` at the root. `split_before`/`split_after` are "finger"
//! splits that walk from the element up to the root, accumulating left and
//! right fragments; `concat` is a standard priority merge. Mark aggregates
//! piggyback on the same `update` discipline as `size`: every node carries
//! an OR of its subtree's marks, so `find_marked` is a plain root-to-leaf
//! descent.

use crate::util::rng::Rng;

use super::{MarkSet, Node, SeedableSequence, Sequence, NIL};

struct TNode {
    left: Node,
    right: Node,
    parent: Node,
    pri: u64,
    size: u32,
    /// node-local marks
    marks: MarkSet,
    /// OR of marks over this node's subtree (maintained by `update`,
    /// exactly like `size`)
    agg: MarkSet,
}

pub struct TreapSeq {
    n: Vec<TNode>,
    free: Vec<Node>,
    rng: Rng,
    live: usize,
}

impl TreapSeq {
    pub fn new(seed: u64) -> Self {
        TreapSeq { n: Vec::new(), free: Vec::new(), rng: Rng::new(seed), live: 0 }
    }

    #[inline]
    fn size(&self, x: Node) -> u32 {
        if x == NIL {
            0
        } else {
            self.n[x as usize].size
        }
    }

    #[inline]
    fn subagg(&self, x: Node) -> MarkSet {
        if x == NIL {
            0
        } else {
            self.n[x as usize].agg
        }
    }

    #[inline]
    fn update(&mut self, x: Node) {
        let l = self.n[x as usize].left;
        let r = self.n[x as usize].right;
        let size = 1 + self.size(l) + self.size(r);
        let agg = self.n[x as usize].marks | self.subagg(l) | self.subagg(r);
        let nd = &mut self.n[x as usize];
        nd.size = size;
        nd.agg = agg;
    }

    fn root_of(&self, mut x: Node) -> Node {
        loop {
            let p = self.n[x as usize].parent;
            if p == NIL {
                return x;
            }
            x = p;
        }
    }

    fn leftmost(&self, mut x: Node) -> Node {
        loop {
            let l = self.n[x as usize].left;
            if l == NIL {
                return x;
            }
            x = l;
        }
    }

    fn rightmost(&self, mut x: Node) -> Node {
        loop {
            let r = self.n[x as usize].right;
            if r == NIL {
                return x;
            }
            x = r;
        }
    }

    /// Merge two treaps (all of `a` precedes all of `b`); returns new root.
    fn merge(&mut self, a: Node, b: Node) -> Node {
        if a == NIL {
            return b;
        }
        if b == NIL {
            return a;
        }
        if self.n[a as usize].pri > self.n[b as usize].pri {
            let ar = self.n[a as usize].right;
            let m = self.merge(ar, b);
            self.n[a as usize].right = m;
            self.n[m as usize].parent = a;
            self.update(a);
            a
        } else {
            let bl = self.n[b as usize].left;
            let m = self.merge(a, bl);
            self.n[b as usize].left = m;
            self.n[m as usize].parent = b;
            self.update(b);
            b
        }
    }
}

impl Sequence for TreapSeq {
    fn new_node(&mut self) -> Node {
        let pri = self.rng.next_u64();
        self.live += 1;
        let fresh = TNode {
            left: NIL,
            right: NIL,
            parent: NIL,
            pri,
            size: 1,
            marks: 0,
            agg: 0,
        };
        if let Some(x) = self.free.pop() {
            self.n[x as usize] = fresh;
            x
        } else {
            self.n.push(fresh);
            (self.n.len() - 1) as Node
        }
    }

    fn free_node(&mut self, x: Node) {
        let nd = &self.n[x as usize];
        assert!(
            nd.left == NIL && nd.right == NIL && nd.parent == NIL,
            "free_node: node {x} is not a singleton"
        );
        self.live -= 1;
        self.free.push(x);
    }

    fn seq_id(&self, x: Node) -> u64 {
        self.root_of(x) as u64
    }

    fn seq_len(&self, x: Node) -> usize {
        self.size(self.root_of(x)) as usize
    }

    fn first_of_seq(&self, x: Node) -> Node {
        self.leftmost(self.root_of(x))
    }

    fn prev(&self, x: Node) -> Option<Node> {
        let l = self.n[x as usize].left;
        if l != NIL {
            return Some(self.rightmost(l));
        }
        let mut cur = x;
        loop {
            let p = self.n[cur as usize].parent;
            if p == NIL {
                return None;
            }
            if self.n[p as usize].right == cur {
                return Some(p);
            }
            cur = p;
        }
    }

    fn next(&self, x: Node) -> Option<Node> {
        let r = self.n[x as usize].right;
        if r != NIL {
            return Some(self.leftmost(r));
        }
        let mut cur = x;
        loop {
            let p = self.n[cur as usize].parent;
            if p == NIL {
                return None;
            }
            if self.n[p as usize].left == cur {
                return Some(p);
            }
            cur = p;
        }
    }

    fn split_before(&mut self, x: Node) {
        // L = everything strictly before x; R = x and after.
        let mut l = self.n[x as usize].left;
        if l != NIL {
            self.n[l as usize].parent = NIL;
            self.n[x as usize].left = NIL;
        }
        self.update(x);
        let mut r = x;
        let mut cur = x;
        let mut p = self.n[x as usize].parent;
        self.n[x as usize].parent = NIL;
        while p != NIL {
            let gp = self.n[p as usize].parent;
            self.n[p as usize].parent = NIL;
            if self.n[p as usize].right == cur {
                // p and its left subtree precede the accumulated left part
                self.n[p as usize].right = NIL;
                self.update(p);
                l = self.merge(p, l);
            } else {
                // p and its right subtree follow the accumulated right part
                self.n[p as usize].left = NIL;
                self.update(p);
                r = self.merge(r, p);
            }
            cur = p;
            p = gp;
        }
        let _ = (l, r); // both now roots with parent == NIL
    }

    fn split_after(&mut self, x: Node) {
        // L = everything up to and including x; R = strictly after.
        let mut r = self.n[x as usize].right;
        if r != NIL {
            self.n[r as usize].parent = NIL;
            self.n[x as usize].right = NIL;
        }
        self.update(x);
        let mut l = x;
        let mut cur = x;
        let mut p = self.n[x as usize].parent;
        self.n[x as usize].parent = NIL;
        while p != NIL {
            let gp = self.n[p as usize].parent;
            self.n[p as usize].parent = NIL;
            if self.n[p as usize].right == cur {
                self.n[p as usize].right = NIL;
                self.update(p);
                l = self.merge(p, l);
            } else {
                self.n[p as usize].left = NIL;
                self.update(p);
                r = self.merge(r, p);
            }
            cur = p;
            p = gp;
        }
        let _ = (l, r);
    }

    fn concat(&mut self, a: Node, b: Node) {
        let ra = self.root_of(a);
        let rb = self.root_of(b);
        assert_ne!(ra, rb, "concat within one sequence");
        self.merge(ra, rb);
    }

    fn live_nodes(&self) -> usize {
        self.live
    }

    fn marks(&self, x: Node) -> MarkSet {
        self.n[x as usize].marks
    }

    fn set_marks(&mut self, x: Node, marks: MarkSet) {
        self.n[x as usize].marks = marks;
        let mut cur = x;
        loop {
            self.update(cur);
            let p = self.n[cur as usize].parent;
            if p == NIL {
                break;
            }
            cur = p;
        }
    }

    fn seq_marks(&self, x: Node) -> MarkSet {
        self.n[self.root_of(x) as usize].agg
    }

    fn find_marked(&self, x: Node, kind: MarkSet) -> Option<Node> {
        let mut cur = self.root_of(x);
        if self.n[cur as usize].agg & kind == 0 {
            return None;
        }
        // descend left-first: the result is the first marked node in
        // sequence order
        loop {
            let nd = &self.n[cur as usize];
            if nd.left != NIL && self.n[nd.left as usize].agg & kind != 0 {
                cur = nd.left;
            } else if nd.marks & kind != 0 {
                return Some(cur);
            } else {
                debug_assert_ne!(nd.right, NIL, "aggregate promised a marked node");
                cur = nd.right;
            }
        }
    }
}

impl SeedableSequence for TreapSeq {
    fn from_seed(seed: u64) -> Self {
        TreapSeq::new(seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{run_prop, Gen};

    /// Oracle: maintain the same sequences as Vec<Vec<Node>> and compare
    /// order, ids, neighbors after random split/concat churn.
    #[test]
    fn treap_sequence_matches_vec_oracle() {
        run_prop("treap seq oracle", 80, |g: &mut Gen| {
            let mut s = TreapSeq::new(g.rng.next_u64());
            crate::ett::testutil::sequence_oracle_scenario(&mut s, g);
        });
    }

    #[test]
    fn singleton_lifecycle() {
        let mut s = TreapSeq::new(1);
        let a = s.new_node();
        assert_eq!(s.seq_len(a), 1);
        assert_eq!(s.prev(a), None);
        assert_eq!(s.next(a), None);
        assert_eq!(s.first_of_seq(a), a);
        s.split_before(a); // no-ops
        s.split_after(a);
        s.free_node(a);
        assert_eq!(s.live_nodes(), 0);
    }
}
