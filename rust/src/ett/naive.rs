//! Naive reference implementations: `O(n)`-per-query oracles for the real
//! backends.
//!
//! * [`NaiveForest`] — adjacency sets + DFS, the [`Forest`] oracle and a
//!   baseline in the `bench_ett` ablation;
//! * [`NaiveSeq`] — Vec-of-Vecs sequences with linear scans, the
//!   differential oracle for the augmented aggregate API ([`Sequence`]
//!   marks) of the treap and skip-list backends.

use std::collections::{BTreeSet, HashMap};

use super::{Forest, MarkSet, Node, SeedableSequence, Sequence, VertexId};

#[derive(Default)]
pub struct NaiveForest {
    adj: Vec<Option<BTreeSet<VertexId>>>,
    free: Vec<VertexId>,
    edges: usize,
}

impl NaiveForest {
    pub fn new() -> Self {
        Self::default()
    }

    fn component(&self, v: VertexId) -> Vec<VertexId> {
        let mut seen = HashMap::new();
        let mut stack = vec![v];
        seen.insert(v, ());
        while let Some(x) = stack.pop() {
            for &y in self.adj[x as usize].as_ref().unwrap() {
                if seen.insert(y, ()).is_none() {
                    stack.push(y);
                }
            }
        }
        let mut out: Vec<VertexId> = seen.into_keys().collect();
        out.sort_unstable();
        out
    }
}

impl Forest for NaiveForest {
    fn add_vertex(&mut self) -> VertexId {
        if let Some(v) = self.free.pop() {
            self.adj[v as usize] = Some(BTreeSet::new());
            v
        } else {
            self.adj.push(Some(BTreeSet::new()));
            (self.adj.len() - 1) as VertexId
        }
    }

    fn remove_vertex(&mut self, v: VertexId) {
        assert!(
            self.adj[v as usize].as_ref().unwrap().is_empty(),
            "remove_vertex: vertex {v} still has incident edges"
        );
        self.adj[v as usize] = None;
        self.free.push(v);
    }

    fn link(&mut self, u: VertexId, v: VertexId) -> bool {
        assert_ne!(u, v);
        if self.connected(u, v) {
            return false;
        }
        self.adj[u as usize].as_mut().unwrap().insert(v);
        self.adj[v as usize].as_mut().unwrap().insert(u);
        self.edges += 1;
        true
    }

    fn cut(&mut self, u: VertexId, v: VertexId) -> bool {
        let removed = self.adj[u as usize].as_mut().unwrap().remove(&v);
        if removed {
            self.adj[v as usize].as_mut().unwrap().remove(&u);
            self.edges -= 1;
        }
        removed
    }

    fn root(&self, v: VertexId) -> u64 {
        // canonical: minimum vertex id in the component
        self.component(v)[0] as u64
    }

    fn component_size(&self, v: VertexId) -> usize {
        self.component(v).len()
    }

    fn degree(&self, v: VertexId) -> usize {
        self.adj[v as usize].as_ref().unwrap().len()
    }

    fn has_edge(&self, u: VertexId, v: VertexId) -> bool {
        self.adj[u as usize]
            .as_ref()
            .map(|s| s.contains(&v))
            .unwrap_or(false)
    }

    fn num_vertices(&self) -> usize {
        self.adj.iter().filter(|a| a.is_some()).count()
    }

    fn num_edges(&self) -> usize {
        self.edges
    }

    fn component_vertices(&self, v: VertexId) -> Vec<VertexId> {
        self.component(v)
    }
}

/// Naive splittable sequence: every sequence is a `Vec<Node>`, every query
/// a linear scan. Implements the full augmented [`Sequence`] API including
/// mark aggregates, which makes it the ground truth the balanced backends
/// are property-tested against.
#[derive(Default)]
pub struct NaiveSeq {
    /// node → index into `seqs` (usize::MAX when free)
    seq_of: Vec<usize>,
    seqs: Vec<Vec<Node>>,
    mk: Vec<MarkSet>,
    free: Vec<Node>,
}

impl NaiveSeq {
    pub fn new() -> Self {
        Self::default()
    }

    fn pos(&self, x: Node) -> (usize, usize) {
        let si = self.seq_of[x as usize];
        let at = self.seqs[si].iter().position(|&y| y == x).unwrap();
        (si, at)
    }

    /// Drop sequence slot `si`, keeping `seqs` dense.
    fn remove_seq(&mut self, si: usize) {
        self.seqs.swap_remove(si);
        if si < self.seqs.len() {
            for &y in &self.seqs[si] {
                self.seq_of[y as usize] = si;
            }
        }
    }
}

impl Sequence for NaiveSeq {
    fn new_node(&mut self) -> Node {
        let x = if let Some(x) = self.free.pop() {
            self.mk[x as usize] = 0;
            x
        } else {
            self.seq_of.push(usize::MAX);
            self.mk.push(0);
            (self.seq_of.len() - 1) as Node
        };
        self.seq_of[x as usize] = self.seqs.len();
        self.seqs.push(vec![x]);
        x
    }

    fn free_node(&mut self, x: Node) {
        let si = self.seq_of[x as usize];
        assert_eq!(self.seqs[si].len(), 1, "free_node: node {x} is not a singleton");
        self.remove_seq(si);
        self.seq_of[x as usize] = usize::MAX;
        self.free.push(x);
    }

    fn seq_id(&self, x: Node) -> u64 {
        // canonical: the current first element (stable between mutations)
        self.seqs[self.seq_of[x as usize]][0] as u64
    }

    fn seq_len(&self, x: Node) -> usize {
        self.seqs[self.seq_of[x as usize]].len()
    }

    fn first_of_seq(&self, x: Node) -> Node {
        self.seqs[self.seq_of[x as usize]][0]
    }

    fn prev(&self, x: Node) -> Option<Node> {
        let (si, at) = self.pos(x);
        if at == 0 {
            None
        } else {
            Some(self.seqs[si][at - 1])
        }
    }

    fn next(&self, x: Node) -> Option<Node> {
        let (si, at) = self.pos(x);
        self.seqs[si].get(at + 1).copied()
    }

    fn split_before(&mut self, x: Node) {
        let (si, at) = self.pos(x);
        if at == 0 {
            return;
        }
        let right = self.seqs[si].split_off(at);
        let ni = self.seqs.len();
        for &y in &right {
            self.seq_of[y as usize] = ni;
        }
        self.seqs.push(right);
    }

    fn split_after(&mut self, x: Node) {
        let (si, at) = self.pos(x);
        if at + 1 == self.seqs[si].len() {
            return;
        }
        let right = self.seqs[si].split_off(at + 1);
        let ni = self.seqs.len();
        for &y in &right {
            self.seq_of[y as usize] = ni;
        }
        self.seqs.push(right);
    }

    fn concat(&mut self, a: Node, b: Node) {
        let sb = self.seq_of[b as usize];
        assert_ne!(self.seq_of[a as usize], sb, "concat within one sequence");
        let bs = std::mem::take(&mut self.seqs[sb]);
        self.remove_seq(sb);
        // re-read: the removal may have moved a's sequence slot
        let sa = self.seq_of[a as usize];
        for &y in &bs {
            self.seq_of[y as usize] = sa;
        }
        self.seqs[sa].extend(bs);
    }

    fn live_nodes(&self) -> usize {
        self.seq_of.len() - self.free.len()
    }

    fn marks(&self, x: Node) -> MarkSet {
        self.mk[x as usize]
    }

    fn set_marks(&mut self, x: Node, marks: MarkSet) {
        self.mk[x as usize] = marks;
    }

    fn seq_marks(&self, x: Node) -> MarkSet {
        self.seqs[self.seq_of[x as usize]]
            .iter()
            .fold(0, |a, &y| a | self.mk[y as usize])
    }

    fn find_marked(&self, x: Node, kind: MarkSet) -> Option<Node> {
        self.seqs[self.seq_of[x as usize]]
            .iter()
            .copied()
            .find(|&y| self.mk[y as usize] & kind != 0)
    }
}

impl SeedableSequence for NaiveSeq {
    fn from_seed(_seed: u64) -> Self {
        NaiveSeq::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn naive_seq_matches_vec_oracle() {
        use crate::util::proptest::{run_prop, Gen};
        run_prop("naive seq oracle", 60, |g: &mut Gen| {
            let mut s = NaiveSeq::new();
            crate::ett::testutil::sequence_oracle_scenario(&mut s, g);
        });
    }

    #[test]
    fn naive_basics() {
        let mut f = NaiveForest::new();
        let a = f.add_vertex();
        let b = f.add_vertex();
        let c = f.add_vertex();
        assert!(f.link(a, b));
        assert!(!f.link(b, a));
        assert!(f.link(b, c));
        assert!(!f.link(a, c));
        assert_eq!(f.root(a), f.root(c));
        assert_eq!(f.component_size(b), 3);
        assert!(f.cut(a, b));
        assert_ne!(f.root(a), f.root(c));
    }
}
