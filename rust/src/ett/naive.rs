//! Naive dynamic forest: adjacency sets + DFS. `O(n)` per query — the test
//! oracle for the Euler-tour backends and a baseline in the `bench_ett`
//! ablation.

use std::collections::{BTreeSet, HashMap};

use super::{Forest, VertexId};

#[derive(Default)]
pub struct NaiveForest {
    adj: Vec<Option<BTreeSet<VertexId>>>,
    free: Vec<VertexId>,
    edges: usize,
}

impl NaiveForest {
    pub fn new() -> Self {
        Self::default()
    }

    fn component(&self, v: VertexId) -> Vec<VertexId> {
        let mut seen = HashMap::new();
        let mut stack = vec![v];
        seen.insert(v, ());
        while let Some(x) = stack.pop() {
            for &y in self.adj[x as usize].as_ref().unwrap() {
                if seen.insert(y, ()).is_none() {
                    stack.push(y);
                }
            }
        }
        let mut out: Vec<VertexId> = seen.into_keys().collect();
        out.sort_unstable();
        out
    }
}

impl Forest for NaiveForest {
    fn add_vertex(&mut self) -> VertexId {
        if let Some(v) = self.free.pop() {
            self.adj[v as usize] = Some(BTreeSet::new());
            v
        } else {
            self.adj.push(Some(BTreeSet::new()));
            (self.adj.len() - 1) as VertexId
        }
    }

    fn remove_vertex(&mut self, v: VertexId) {
        assert!(
            self.adj[v as usize].as_ref().unwrap().is_empty(),
            "remove_vertex: vertex {v} still has incident edges"
        );
        self.adj[v as usize] = None;
        self.free.push(v);
    }

    fn link(&mut self, u: VertexId, v: VertexId) -> bool {
        assert_ne!(u, v);
        if self.connected(u, v) {
            return false;
        }
        self.adj[u as usize].as_mut().unwrap().insert(v);
        self.adj[v as usize].as_mut().unwrap().insert(u);
        self.edges += 1;
        true
    }

    fn cut(&mut self, u: VertexId, v: VertexId) -> bool {
        let removed = self.adj[u as usize].as_mut().unwrap().remove(&v);
        if removed {
            self.adj[v as usize].as_mut().unwrap().remove(&u);
            self.edges -= 1;
        }
        removed
    }

    fn root(&self, v: VertexId) -> u64 {
        // canonical: minimum vertex id in the component
        self.component(v)[0] as u64
    }

    fn component_size(&self, v: VertexId) -> usize {
        self.component(v).len()
    }

    fn degree(&self, v: VertexId) -> usize {
        self.adj[v as usize].as_ref().unwrap().len()
    }

    fn has_edge(&self, u: VertexId, v: VertexId) -> bool {
        self.adj[u as usize]
            .as_ref()
            .map(|s| s.contains(&v))
            .unwrap_or(false)
    }

    fn num_vertices(&self) -> usize {
        self.adj.iter().filter(|a| a.is_some()).count()
    }

    fn num_edges(&self) -> usize {
        self.edges
    }

    fn component_vertices(&self, v: VertexId) -> Vec<VertexId> {
        self.component(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn naive_basics() {
        let mut f = NaiveForest::new();
        let a = f.add_vertex();
        let b = f.add_vertex();
        let c = f.add_vertex();
        assert!(f.link(a, b));
        assert!(!f.link(b, a));
        assert!(f.link(b, c));
        assert!(!f.link(a, c));
        assert_eq!(f.root(a), f.root(c));
        assert_eq!(f.component_size(b), 3);
        assert!(f.cut(a, b));
        assert_ne!(f.root(a), f.root(c));
    }
}
