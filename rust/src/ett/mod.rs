//! Euler Tour Trees: the dynamic-forest data structure of the paper.
//!
//! A forest on vertices supports `link`, `cut`, `root` (a canonical cluster
//! identifier) and connectivity queries in `O(log n)` by storing the **Euler
//! tour sequence** of every tree in a balanced sequence structure
//! (Henzinger & King '95). Two interchangeable sequence backends are
//! provided:
//!
//! * [`treap::TreapSeq`] — randomized balanced BST (the classic
//!   Henzinger–King realization);
//! * [`skiplist::SkipSeq`] — indexable skip list (the Tseng–Dhulipala–
//!   Blelloch '19 realization the paper adopts).
//!
//! Both implement the [`Sequence`] trait; [`EulerForest`] contains all the
//! Euler-tour logic generically, so the two backends are exactly comparable
//! in the `bench_ett` ablation.
//!
//! ## Representation
//!
//! The tour of a tree rooted at `r` is the arc sequence
//! `tour(r) = (r,r) ⧺ [(r,c) ⧺ tour(c) ⧺ (c,r) for each child c]`,
//! i.e. one *loop arc* per vertex and two *edge arcs* per tree edge, so a
//! tree with `v` vertices has a tour of length `3v − 2`. With this encoding:
//!
//! * `link(u,v)`  = reroot both tours and concatenate with the two new arcs;
//! * `cut(u,v)`   = split out the sub-sequence between the two edge arcs;
//! * `root(v)`    = canonical id of the sequence containing v's loop arc;
//! * `size(v)`    = `(len + 2) / 3`.

pub mod naive;
pub mod skiplist;
pub mod treap;

use rustc_hash::FxHashMap;

/// Handle to a sequence element. `u32::MAX` is reserved as NIL internally.
pub type Node = u32;
pub const NIL: Node = u32::MAX;

/// Vertex identifier within a forest.
pub type VertexId = u32;

/// Bitmask of per-node marks, maintained by every [`Sequence`] backend as
/// OR-combined subtree aggregates so that "does this sequence contain a
/// marked node, and where?" is answerable in `O(log n)`. The leveled
/// connectivity structure ([`crate::dbscan::leveled`]) stores two kinds per
/// Euler tour: [`MARK_VERTEX`] on loop arcs and [`MARK_EDGE`] on edge arcs.
pub type MarkSet = u8;
/// Loop-arc mark: this vertex owns a level-ℓ non-tree edge.
pub const MARK_VERTEX: MarkSet = 1;
/// Edge-arc mark: this arc realizes a level-ℓ tree edge.
pub const MARK_EDGE: MarkSet = 2;

/// A splittable, joinable sequence of elements with canonical per-sequence
/// identifiers. This is the exact interface Euler tour trees need; both the
/// treap and the skip-list provide it in `O(log n)` expected per call.
pub trait Sequence {
    /// Allocate a fresh element forming its own singleton sequence.
    fn new_node(&mut self) -> Node;
    /// Free an element. Must currently be a singleton sequence.
    fn free_node(&mut self, x: Node);
    /// Canonical identifier of x's sequence — stable between mutations.
    fn seq_id(&self, x: Node) -> u64;
    /// Are a and b in the same sequence?
    fn same_seq(&self, a: Node, b: Node) -> bool {
        self.seq_id(a) == self.seq_id(b)
    }
    /// Number of elements in x's sequence.
    fn seq_len(&self, x: Node) -> usize;
    /// First element of x's sequence.
    fn first_of_seq(&self, x: Node) -> Node;
    /// In-sequence predecessor / successor.
    fn prev(&self, x: Node) -> Option<Node>;
    fn next(&self, x: Node) -> Option<Node>;
    /// Split x's sequence so that x becomes the first element of a new
    /// sequence (no-op when x is already first).
    fn split_before(&mut self, x: Node);
    /// Split x's sequence so that x becomes the last element (no-op when x
    /// is already last).
    fn split_after(&mut self, x: Node);
    /// Concatenate: sequence containing `a` followed by sequence containing
    /// `b`. Must be different sequences.
    fn concat(&mut self, a: Node, b: Node);
    /// Number of live elements (for leak tests).
    fn live_nodes(&self) -> usize;
    /// Node-local marks of `x` (not aggregated).
    fn marks(&self, x: Node) -> MarkSet;
    /// Replace `x`'s node-local marks, repairing the subtree aggregates
    /// along `x`'s access path so [`Sequence::seq_marks`] and
    /// [`Sequence::find_marked`] stay `O(log n)`.
    fn set_marks(&mut self, x: Node, marks: MarkSet);
    /// OR of the marks of every node in `x`'s sequence.
    fn seq_marks(&self, x: Node) -> MarkSet;
    /// First node in sequence order whose marks intersect `kind`, if any.
    fn find_marked(&self, x: Node, kind: MarkSet) -> Option<Node>;
}

/// Backends constructible from a bare seed — lets generic containers (the
/// leveled connectivity hierarchy) spawn per-level forests on demand.
pub trait SeedableSequence: Sequence {
    fn from_seed(seed: u64) -> Self;
}

/// Dynamic forest interface consumed by the DBSCAN layer (and by the test
/// oracle comparisons).
pub trait Forest {
    fn add_vertex(&mut self) -> VertexId;
    /// Remove an isolated vertex (degree 0). Panics otherwise.
    fn remove_vertex(&mut self, v: VertexId);
    /// Add edge {u,v} iff u, v are in different trees. Returns whether the
    /// edge was added.
    fn link(&mut self, u: VertexId, v: VertexId) -> bool;
    /// Remove edge {u,v} if it exists. Returns whether an edge was removed.
    fn cut(&mut self, u: VertexId, v: VertexId) -> bool;
    /// Canonical identifier of v's tree — stable until the next mutation.
    fn root(&self, v: VertexId) -> u64;
    fn connected(&self, u: VertexId, v: VertexId) -> bool {
        self.root(u) == self.root(v)
    }
    /// Number of vertices in v's tree.
    fn component_size(&self, v: VertexId) -> usize;
    /// Degree of v in the forest.
    fn degree(&self, v: VertexId) -> usize;
    fn has_edge(&self, u: VertexId, v: VertexId) -> bool;
    fn num_vertices(&self) -> usize;
    fn num_edges(&self) -> usize;
    /// All vertices of v's tree, O(component size). Used by the
    /// replacement-search connectivity repair (see `dbscan::connectivity`).
    fn component_vertices(&self, v: VertexId) -> Vec<VertexId>;
}

/// Euler-tour forest over any [`Sequence`] backend.
pub struct EulerForest<S: Sequence> {
    seq: S,
    /// loop-arc node per vertex (NIL in freed slots).
    verts: Vec<Node>,
    degree: Vec<u32>,
    free_verts: Vec<VertexId>,
    /// {u,v} (u<v) → (arc u→v, arc v→u)
    edges: FxHashMap<(VertexId, VertexId), (Node, Node)>,
    /// loop arc → vertex (inverse of `verts`; used by tour traversal)
    loop_of: FxHashMap<Node, VertexId>,
    /// canonical (min→max) edge arc → edge (inverse of `edges`; resolves
    /// the arcs found by the marked-edge search back to vertex pairs)
    edge_of: FxHashMap<Node, (VertexId, VertexId)>,
    live: usize,
}

#[inline]
fn ekey(u: VertexId, v: VertexId) -> (VertexId, VertexId) {
    if u < v {
        (u, v)
    } else {
        (v, u)
    }
}

impl<S: Sequence> EulerForest<S> {
    pub fn with_backend(seq: S) -> Self {
        EulerForest {
            seq,
            verts: Vec::new(),
            degree: Vec::new(),
            free_verts: Vec::new(),
            edges: FxHashMap::default(),
            loop_of: FxHashMap::default(),
            edge_of: FxHashMap::default(),
            live: 0,
        }
    }

    #[inline]
    fn loop_node(&self, v: VertexId) -> Node {
        let n = self.verts[v as usize];
        debug_assert_ne!(n, NIL, "vertex {v} is not live");
        n
    }

    /// Rotate v's tour so it starts at v's loop arc.
    fn reroot(&mut self, v: VertexId) {
        let lv = self.loop_node(v);
        let first = self.seq.first_of_seq(lv);
        if first != lv {
            self.seq.split_before(lv);
            // tour = B(starting at lv) ++ A(starting at old first)
            self.seq.concat(lv, first);
        }
    }

    // ------------------------------------------------------------------
    // mark aggregates (the leveled-connectivity API)
    // ------------------------------------------------------------------

    /// Set/clear the vertex mark on v's loop arc.
    pub fn set_vertex_mark(&mut self, v: VertexId, on: bool) {
        let n = self.loop_node(v);
        let m = self.seq.marks(n);
        let want = if on { m | MARK_VERTEX } else { m & !MARK_VERTEX };
        if want != m {
            self.seq.set_marks(n, want);
        }
    }

    pub fn vertex_mark(&self, v: VertexId) -> bool {
        self.seq.marks(self.loop_node(v)) & MARK_VERTEX != 0
    }

    /// Set/clear the edge mark on the canonical arc of tree edge {u,v}.
    /// Panics if the edge is not in the forest.
    pub fn set_edge_mark(&mut self, u: VertexId, v: VertexId, on: bool) {
        let (a, _) = self.edges[&ekey(u, v)];
        let m = self.seq.marks(a);
        let want = if on { m | MARK_EDGE } else { m & !MARK_EDGE };
        if want != m {
            self.seq.set_marks(a, want);
        }
    }

    /// First marked vertex in v's tree (tour order), if any — `O(log n)`.
    pub fn find_marked_vertex(&self, v: VertexId) -> Option<VertexId> {
        let n = self.seq.find_marked(self.loop_node(v), MARK_VERTEX)?;
        Some(self.loop_of[&n])
    }

    /// First marked tree edge in v's tree (tour order), if any —
    /// `O(log n)`.
    pub fn find_marked_edge(&self, v: VertexId) -> Option<(VertexId, VertexId)> {
        let n = self.seq.find_marked(self.loop_node(v), MARK_EDGE)?;
        Some(self.edge_of[&n])
    }

    // ------------------------------------------------------------------
    // mirrored vertex ids (the per-level forests of the leveled
    // connectivity structure share the ids allocated by its level-0
    // forest rather than running their own allocators)
    // ------------------------------------------------------------------

    /// Is `v` live in this forest?
    pub fn has_vertex(&self, v: VertexId) -> bool {
        (v as usize) < self.verts.len() && self.verts[v as usize] != NIL
    }

    /// Materialize externally allocated vertex id `v` in this forest
    /// (no-op when already live). Never touches the forest's own free
    /// list — pair with [`EulerForest::retire_vertex`].
    pub fn ensure_vertex(&mut self, v: VertexId) {
        let idx = v as usize;
        if idx >= self.verts.len() {
            self.verts.resize(idx + 1, NIL);
            self.degree.resize(idx + 1, 0);
        }
        if self.verts[idx] != NIL {
            return;
        }
        let n = self.seq.new_node();
        self.live += 1;
        self.verts[idx] = n;
        self.degree[idx] = 0;
        self.loop_of.insert(n, v);
    }

    /// Free `v`'s loop arc WITHOUT recycling the id (the id allocator is
    /// elsewhere). `v` must be isolated (degree 0).
    pub fn retire_vertex(&mut self, v: VertexId) {
        assert_eq!(
            self.degree[v as usize], 0,
            "retire_vertex: vertex {v} still has incident edges"
        );
        let n = self.loop_node(v);
        debug_assert_eq!(self.seq.seq_len(n), 1);
        self.seq.free_node(n);
        self.loop_of.remove(&n);
        self.live -= 1;
        self.verts[v as usize] = NIL;
    }

    /// Live vertices in this forest (mirror forests included — unlike
    /// [`Forest::num_vertices`] this ignores the free list).
    pub fn live_vertex_count(&self) -> usize {
        self.loop_of.len()
    }

    /// Live tree edges in this forest — half the total degree (every
    /// `link` adds one to both endpoints). `O(verts)`; sampled by the
    /// observability layer's structural gauges at publish, never on the
    /// per-op path.
    pub fn tree_edge_count(&self) -> usize {
        self.degree.iter().map(|&d| d as usize).sum::<usize>() / 2
    }

    /// Visit every vertex of `v`'s tree in tour order — `O(component
    /// size)`. This is **not** a replacement-search primitive (that cost
    /// is exactly what the leveled connectivity's mark aggregates remove —
    /// see `rust/tests/lint.rs`): it backs the stable-component event
    /// plumbing of `dbscan::leveled`, where the walk only ever covers the
    /// side of a genuine merge/split whose cluster identity changed, so
    /// its cost is charged to points that must be relabeled anyway.
    pub fn for_each_tree_vertex(&self, v: VertexId, f: &mut dyn FnMut(VertexId)) {
        let lv = self.loop_node(v);
        let mut cur = Some(self.seq.first_of_seq(lv));
        while let Some(n) = cur {
            if let Some(&w) = self.loop_of.get(&n) {
                f(w);
            }
            cur = self.seq.next(n);
        }
    }
}

impl<S: Sequence> Forest for EulerForest<S> {
    fn add_vertex(&mut self) -> VertexId {
        let n = self.seq.new_node();
        self.live += 1;
        let v = if let Some(v) = self.free_verts.pop() {
            self.verts[v as usize] = n;
            self.degree[v as usize] = 0;
            v
        } else {
            self.verts.push(n);
            self.degree.push(0);
            (self.verts.len() - 1) as VertexId
        };
        self.loop_of.insert(n, v);
        v
    }

    fn remove_vertex(&mut self, v: VertexId) {
        assert_eq!(
            self.degree[v as usize], 0,
            "remove_vertex: vertex {v} still has incident edges"
        );
        let n = self.loop_node(v);
        debug_assert_eq!(self.seq.seq_len(n), 1);
        self.seq.free_node(n);
        self.loop_of.remove(&n);
        self.live -= 1;
        self.verts[v as usize] = NIL;
        self.free_verts.push(v);
    }

    fn link(&mut self, u: VertexId, v: VertexId) -> bool {
        assert_ne!(u, v, "self-loops are not allowed");
        let lu = self.loop_node(u);
        let lv = self.loop_node(v);
        if self.seq.same_seq(lu, lv) {
            return false;
        }
        self.reroot(u);
        self.reroot(v);
        let auv = self.seq.new_node();
        let avu = self.seq.new_node();
        self.live += 2;
        // Tu ++ (u,v) ++ Tv ++ (v,u)
        self.seq.concat(lu, auv);
        self.seq.concat(lu, lv);
        self.seq.concat(lu, avu);
        let (a, b) = if u < v { (auv, avu) } else { (avu, auv) };
        self.edges.insert(ekey(u, v), (a, b));
        self.edge_of.insert(a, ekey(u, v));
        self.degree[u as usize] += 1;
        self.degree[v as usize] += 1;
        true
    }

    fn cut(&mut self, u: VertexId, v: VertexId) -> bool {
        let Some((a, b)) = self.edges.remove(&ekey(u, v)) else {
            return false;
        };
        self.edge_of.remove(&a);
        // The tour is S = A ⧺ [n1] ⧺ M ⧺ [n2] ⧺ C where {n1,n2} = {a,b} in
        // unknown order; M is the inner subtree's tour, A ⧺ C the outer's.
        // Capture the boundary neighbors before any splits.
        let pa = self.seq.prev(a);
        let pb = self.seq.prev(b);
        // After split_before(a): if b is still with a, a precedes b.
        self.seq.split_before(a);
        let (n1, n2, a_last) =
            if self.seq.same_seq(a, b) { (a, b, pa) } else { (b, a, pb) };
        if n1 != a {
            self.seq.split_before(n1); // [A] | [n1 M n2 C]
        }
        self.seq.split_after(n1); // [n1] | [M n2 C]
        self.seq.split_before(n2); // [M] | [n2 C]
        let c_first = self.seq.next(n2);
        self.seq.split_after(n2); // [n2] | [C]
        // Outer tour: A ⧺ C (either side may be absent).
        if let (Some(al), Some(cf)) = (a_last, c_first) {
            self.seq.concat(al, cf);
        }
        debug_assert_eq!(self.seq.seq_len(n1), 1);
        debug_assert_eq!(self.seq.seq_len(n2), 1);
        self.seq.free_node(n1);
        self.seq.free_node(n2);
        self.live -= 2;
        self.degree[u as usize] -= 1;
        self.degree[v as usize] -= 1;
        true
    }

    fn root(&self, v: VertexId) -> u64 {
        self.seq.seq_id(self.loop_node(v))
    }

    fn component_size(&self, v: VertexId) -> usize {
        let len = self.seq.seq_len(self.loop_node(v));
        debug_assert_eq!((len + 2) % 3, 0, "tour length {len} malformed");
        (len + 2) / 3
    }

    fn degree(&self, v: VertexId) -> usize {
        self.degree[v as usize] as usize
    }

    fn has_edge(&self, u: VertexId, v: VertexId) -> bool {
        self.edges.contains_key(&ekey(u, v))
    }

    fn num_vertices(&self) -> usize {
        self.verts.len() - self.free_verts.len()
    }

    fn num_edges(&self) -> usize {
        self.edges.len()
    }

    fn component_vertices(&self, v: VertexId) -> Vec<VertexId> {
        // walk the Euler tour once, collecting loop arcs
        let lv = self.loop_node(v);
        let mut out = Vec::new();
        let mut cur = Some(self.seq.first_of_seq(lv));
        while let Some(n) = cur {
            if let Some(&w) = self.loop_of.get(&n) {
                out.push(w);
            }
            cur = self.seq.next(n);
        }
        out
    }
}

/// The default (paper) forest: skip-list Euler tour sequences.
pub type SkipForest = EulerForest<skiplist::SkipSeq>;
/// Henzinger–King style balanced-BST forest.
pub type TreapForest = EulerForest<treap::TreapSeq>;

impl SkipForest {
    pub fn new(seed: u64) -> Self {
        EulerForest::with_backend(skiplist::SkipSeq::new(seed))
    }
}

impl TreapForest {
    pub fn new(seed: u64) -> Self {
        EulerForest::with_backend(treap::TreapSeq::new(seed))
    }
}

/// Shared test scenario: drive a [`Sequence`] implementation against a
/// `Vec<Vec<Node>>` oracle under random split/concat/mark churn, auditing
/// order, ids, lengths, neighbors and mark aggregates after every op.
#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::util::proptest::Gen;

    pub(crate) fn sequence_oracle_scenario<S: Sequence>(s: &mut S, g: &mut Gen) {
        let n = g.usize_in(1..=20);
        let mut seqs: Vec<Vec<Node>> = (0..n).map(|_| vec![s.new_node()]).collect();
        let mut marks: FxHashMap<Node, MarkSet> = FxHashMap::default();
        let ops = g.usize_in(0..=80);
        for _ in 0..ops {
            match g.usize_in(0..=3) {
                0 => {
                    // concat two random distinct sequences
                    if seqs.len() >= 2 {
                        let i = g.usize_in(0..=seqs.len() - 1);
                        let mut j = g.usize_in(0..=seqs.len() - 1);
                        if i == j {
                            j = (j + 1) % seqs.len();
                        }
                        let (i, j) = (i.min(j), i.max(j));
                        let b = seqs.remove(j);
                        let pa = *g.choose(&seqs[i]);
                        let pb = *g.choose(&b);
                        s.concat(pa, pb);
                        seqs[i].extend(b);
                    }
                }
                1 => {
                    // split a random sequence before a random element
                    let i = g.usize_in(0..=seqs.len() - 1);
                    let at = g.usize_in(0..=seqs[i].len() - 1);
                    s.split_before(seqs[i][at]);
                    if at > 0 {
                        let right = seqs[i].split_off(at);
                        seqs.push(right);
                    }
                }
                2 => {
                    // split after
                    let i = g.usize_in(0..=seqs.len() - 1);
                    let at = g.usize_in(0..=seqs[i].len() - 1);
                    s.split_after(seqs[i][at]);
                    if at + 1 < seqs[i].len() {
                        let right = seqs[i].split_off(at + 1);
                        seqs.push(right);
                    }
                }
                _ => {
                    // re-mark a random element
                    let i = g.usize_in(0..=seqs.len() - 1);
                    let x = *g.choose(&seqs[i]);
                    let m = g.usize_in(0..=3) as MarkSet;
                    s.set_marks(x, m);
                    marks.insert(x, m);
                }
            }
            // audit everything
            for seq in &seqs {
                let id = s.seq_id(seq[0]);
                assert_eq!(s.seq_len(seq[0]), seq.len());
                assert_eq!(s.first_of_seq(*seq.last().unwrap()), seq[0]);
                let mut agg: MarkSet = 0;
                for (k, &x) in seq.iter().enumerate() {
                    assert_eq!(s.seq_id(x), id, "consistent id within seq");
                    let want_prev = if k > 0 { Some(seq[k - 1]) } else { None };
                    let want_next =
                        if k + 1 < seq.len() { Some(seq[k + 1]) } else { None };
                    assert_eq!(s.prev(x), want_prev, "prev of pos {k}");
                    assert_eq!(s.next(x), want_next, "next of pos {k}");
                    let m = marks.get(&x).copied().unwrap_or(0);
                    assert_eq!(s.marks(x), m, "node marks of pos {k}");
                    agg |= m;
                }
                assert_eq!(s.seq_marks(seq[0]), agg, "sequence mark aggregate");
                for kind in [MARK_VERTEX, MARK_EDGE] {
                    let want = seq
                        .iter()
                        .copied()
                        .find(|x| marks.get(x).copied().unwrap_or(0) & kind != 0);
                    let probe = *g.choose(seq);
                    assert_eq!(
                        s.find_marked(probe, kind),
                        want,
                        "first marked node for kind {kind}"
                    );
                }
            }
            // distinct sequences must have distinct ids
            let ids: Vec<u64> = seqs.iter().map(|q| s.seq_id(q[0])).collect();
            let mut dedup = ids.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), seqs.len(), "id collision across sequences");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::naive::NaiveForest;
    use super::*;
    use crate::util::proptest::{run_prop, Gen};

    fn forest_smoke<F: Forest>(mut f: F) {
        let a = f.add_vertex();
        let b = f.add_vertex();
        let c = f.add_vertex();
        let d = f.add_vertex();
        assert!(!f.connected(a, b));
        assert!(f.link(a, b));
        assert!(!f.link(a, b), "duplicate link must be rejected");
        assert!(f.link(c, d));
        assert!(!f.connected(a, c));
        assert!(f.link(b, c));
        assert!(f.connected(a, d));
        assert!(!f.link(a, d), "cycle link must be rejected");
        assert_eq!(f.component_size(a), 4);
        assert_eq!(f.degree(b), 2);
        assert!(f.cut(b, c));
        assert!(!f.cut(b, c));
        assert!(!f.connected(a, c));
        assert_eq!(f.component_size(a), 2);
        assert_eq!(f.component_size(c), 2);
        assert!(f.cut(a, b));
        assert!(f.cut(c, d));
        for v in [a, b, c, d] {
            assert_eq!(f.component_size(v), 1);
            f.remove_vertex(v);
        }
        assert_eq!(f.num_vertices(), 0);
        assert_eq!(f.num_edges(), 0);
    }

    #[test]
    fn treap_smoke() {
        forest_smoke(TreapForest::new(1));
    }

    #[test]
    fn tree_edge_count_tracks_links_and_cuts() {
        let mut f = TreapForest::new(3);
        let a = f.add_vertex();
        let b = f.add_vertex();
        let c = f.add_vertex();
        assert_eq!(f.tree_edge_count(), 0);
        assert!(f.link(a, b));
        assert!(f.link(b, c));
        assert_eq!(f.tree_edge_count(), 2);
        assert!(f.cut(a, b));
        assert_eq!(f.tree_edge_count(), 1);
    }

    #[test]
    fn skiplist_smoke() {
        forest_smoke(SkipForest::new(1));
    }

    /// Drive random link/cut/remove sequences and compare connectivity,
    /// component sizes and degrees against the DFS oracle.
    fn forest_matches_oracle<F: Forest>(make: impl Fn(u64) -> F) {
        run_prop("forest matches naive oracle", 60, |g: &mut Gen| {
            let n = g.usize_in(2..=24);
            let mut f = make(g.rng.next_u64());
            let mut o = NaiveForest::new();
            let vf: Vec<VertexId> = (0..n).map(|_| f.add_vertex()).collect();
            let vo: Vec<VertexId> = (0..n).map(|_| o.add_vertex()).collect();
            let ops = g.usize_in(1..=120);
            let mut edges: Vec<(usize, usize)> = Vec::new();
            for _ in 0..ops {
                let a = g.usize_in(0..=n - 1);
                let mut b = g.usize_in(0..=n - 1);
                if a == b {
                    b = (b + 1) % n;
                }
                match g.usize_in(0..=2) {
                    0 => {
                        let rf = f.link(vf[a], vf[b]);
                        let ro = o.link(vo[a], vo[b]);
                        assert_eq!(rf, ro, "link({a},{b}) disagreement");
                        if rf {
                            edges.push((a, b));
                        }
                    }
                    1 => {
                        // cut a random existing edge (or a non-edge probe)
                        if !edges.is_empty() && g.rng.coin(0.8) {
                            let i = g.usize_in(0..=edges.len() - 1);
                            let (x, y) = edges.swap_remove(i);
                            assert!(f.cut(vf[x], vf[y]));
                            assert!(o.cut(vo[x], vo[y]));
                        } else {
                            let rf = f.cut(vf[a], vf[b]);
                            let ro = o.cut(vo[a], vo[b]);
                            assert_eq!(rf, ro);
                            if rf {
                                edges.retain(|&(x, y)| {
                                    (x, y) != (a, b) && (x, y) != (b, a)
                                });
                            }
                        }
                    }
                    _ => {
                        // consistency audit of the full state
                        for i in 0..n {
                            assert_eq!(
                                f.component_size(vf[i]),
                                o.component_size(vo[i]),
                                "component size of {i}"
                            );
                            assert_eq!(f.degree(vf[i]), o.degree(vo[i]));
                            for j in 0..n {
                                assert_eq!(
                                    f.connected(vf[i], vf[j]),
                                    o.connected(vo[i], vo[j]),
                                    "connectivity({i},{j})"
                                );
                            }
                        }
                    }
                }
            }
            // root must be identical within components, distinct across
            let mut seen: std::collections::HashMap<u64, u64> =
                std::collections::HashMap::new();
            for i in 0..n {
                let rf = f.root(vf[i]);
                let ro = o.root(vo[i]);
                match seen.get(&ro) {
                    Some(&expect) => assert_eq!(rf, expect),
                    None => {
                        assert!(
                            !seen.values().any(|&x| x == rf),
                            "distinct components share a root id"
                        );
                        seen.insert(ro, rf);
                    }
                }
            }
        });
    }

    #[test]
    fn treap_matches_oracle() {
        forest_matches_oracle(TreapForest::new);
    }

    #[test]
    fn skiplist_matches_oracle() {
        forest_matches_oracle(SkipForest::new);
    }

    #[test]
    fn no_node_leaks_after_churn() {
        let mut f = TreapForest::new(3);
        let vs: Vec<_> = (0..10).map(|_| f.add_vertex()).collect();
        for w in 1..10 {
            f.link(vs[0], vs[w]);
        }
        for w in 1..10 {
            f.cut(vs[0], vs[w]);
        }
        for &v in &vs {
            f.remove_vertex(v);
        }
        assert_eq!(f.seq.live_nodes(), 0);
    }

    #[test]
    #[should_panic(expected = "still has incident edges")]
    fn remove_nonisolated_panics() {
        let mut f = TreapForest::new(4);
        let a = f.add_vertex();
        let b = f.add_vertex();
        f.link(a, b);
        f.remove_vertex(a);
    }

    /// Satellite differential test: the treap and skip-list aggregate
    /// marks are checked against `naive::NaiveSeq` (which implements the
    /// augmented API by linear scan) across randomized join/split/mark
    /// schedules — every backend sees the identical logical schedule.
    #[test]
    fn aggregate_marks_agree_with_naive_oracle() {
        use super::naive::NaiveSeq;
        use super::skiplist::SkipSeq;
        use super::treap::TreapSeq;
        run_prop("aggregate marks vs NaiveSeq", 40, |g: &mut Gen| {
            let n = g.usize_in(1..=16);
            let mut tr = TreapSeq::from_seed(g.rng.next_u64());
            let mut sk = SkipSeq::from_seed(g.rng.next_u64());
            let mut na = NaiveSeq::from_seed(0);
            let tn: Vec<Node> = (0..n).map(|_| tr.new_node()).collect();
            let sn: Vec<Node> = (0..n).map(|_| sk.new_node()).collect();
            let nn: Vec<Node> = (0..n).map(|_| na.new_node()).collect();
            // logical sequences hold indices into tn/sn/nn
            let mut seqs: Vec<Vec<usize>> = (0..n).map(|i| vec![i]).collect();
            for _ in 0..g.usize_in(0..=60) {
                match g.usize_in(0..=3) {
                    0 => {
                        if seqs.len() >= 2 {
                            let i = g.usize_in(0..=seqs.len() - 1);
                            let mut j = g.usize_in(0..=seqs.len() - 1);
                            if i == j {
                                j = (j + 1) % seqs.len();
                            }
                            let (i, j) = (i.min(j), i.max(j));
                            let b = seqs.remove(j);
                            let (pa, pb) = (seqs[i][0], b[0]);
                            tr.concat(tn[pa], tn[pb]);
                            sk.concat(sn[pa], sn[pb]);
                            na.concat(nn[pa], nn[pb]);
                            seqs[i].extend(b);
                        }
                    }
                    1 => {
                        let i = g.usize_in(0..=seqs.len() - 1);
                        let at = g.usize_in(0..=seqs[i].len() - 1);
                        let x = seqs[i][at];
                        tr.split_before(tn[x]);
                        sk.split_before(sn[x]);
                        na.split_before(nn[x]);
                        if at > 0 {
                            let right = seqs[i].split_off(at);
                            seqs.push(right);
                        }
                    }
                    2 => {
                        let i = g.usize_in(0..=seqs.len() - 1);
                        let at = g.usize_in(0..=seqs[i].len() - 1);
                        let x = seqs[i][at];
                        tr.split_after(tn[x]);
                        sk.split_after(sn[x]);
                        na.split_after(nn[x]);
                        if at + 1 < seqs[i].len() {
                            let right = seqs[i].split_off(at + 1);
                            seqs.push(right);
                        }
                    }
                    _ => {
                        let i = g.usize_in(0..=seqs.len() - 1);
                        let x = *g.choose(&seqs[i]);
                        let m = g.usize_in(0..=3) as MarkSet;
                        tr.set_marks(tn[x], m);
                        sk.set_marks(sn[x], m);
                        na.set_marks(nn[x], m);
                    }
                }
                // the naive backend is the ground truth for every query
                for q in &seqs {
                    let probe = *g.choose(q);
                    let want = na.seq_marks(nn[probe]);
                    assert_eq!(tr.seq_marks(tn[probe]), want, "treap seq_marks");
                    assert_eq!(sk.seq_marks(sn[probe]), want, "skiplist seq_marks");
                    for kind in [MARK_VERTEX, MARK_EDGE] {
                        let pos = |v: &[Node], x: Node| {
                            v.iter().position(|&y| y == x).unwrap()
                        };
                        let want =
                            na.find_marked(nn[probe], kind).map(|x| pos(&nn, x));
                        let got_t =
                            tr.find_marked(tn[probe], kind).map(|x| pos(&tn, x));
                        let got_s =
                            sk.find_marked(sn[probe], kind).map(|x| pos(&sn, x));
                        assert_eq!(got_t, want, "treap find_marked kind {kind}");
                        assert_eq!(got_s, want, "skiplist find_marked kind {kind}");
                    }
                }
            }
        });
    }

    /// Satellite audit (skip-list `any_marks` fast path): while a backend
    /// instance has never carried a nonzero mark, split/concat skip the
    /// span-aggregate repair entirely. The hazard audited here is a stale
    /// never-marked state after `concat` of a marked and an unmarked
    /// sequence — directed schedules drive exactly those transitions
    /// (first mark late in the instance's life, marked⧺unmarked and
    /// unmarked⧺marked concats, splits straddling the mark, re-clearing)
    /// against `NaiveSeq` on both real backends. Audit conclusion: the
    /// flag is *instance*-global, not per-sequence, so a marked sequence
    /// always flips repairs on for every sequence in the backend — the
    /// schedules below pin that behaviour against regressions (e.g. a
    /// future per-sequence flag that forgets concat can move marks into a
    /// "never-marked" sequence).
    #[test]
    fn concat_after_mark_keeps_aggregates_fresh() {
        use super::naive::NaiveSeq;
        use super::skiplist::SkipSeq;
        use super::treap::TreapSeq;

        fn check<S: Sequence>(s: &S, n: &NaiveSeq, sx: &[Node], nx: &[Node], ctx: &str) {
            for (i, (&a, &b)) in sx.iter().zip(nx.iter()).enumerate() {
                assert_eq!(
                    s.seq_marks(a),
                    n.seq_marks(b),
                    "{ctx}: seq_marks via element {i}"
                );
                for kind in [MARK_VERTEX, MARK_EDGE] {
                    let want = n
                        .find_marked(b, kind)
                        .map(|x| nx.iter().position(|&y| y == x).unwrap());
                    let got = s
                        .find_marked(a, kind)
                        .map(|x| sx.iter().position(|&y| y == x).unwrap());
                    assert_eq!(got, want, "{ctx}: find_marked({kind}) via {i}");
                }
            }
        }

        fn run<S: SeedableSequence>(seed: u64) {
            let mut s = S::from_seed(seed);
            let mut n = NaiveSeq::from_seed(0);
            // two sequences of 6: A = x[0..6], B = x[6..12], built while the
            // instance is still mark-free (fast path active)
            let sx: Vec<Node> = (0..12).map(|_| s.new_node()).collect();
            let nx: Vec<Node> = (0..12).map(|_| n.new_node()).collect();
            for w in 0..5 {
                s.concat(sx[w], sx[w + 1]);
                n.concat(nx[w], nx[w + 1]);
                s.concat(sx[6 + w], sx[6 + w + 1]);
                n.concat(nx[6 + w], nx[6 + w + 1]);
            }
            check(&s, &n, &sx, &nx, "pre-mark");
            // first mark ever, deep inside A — the never-marked state ends
            s.set_marks(sx[3], MARK_VERTEX);
            n.set_marks(nx[3], MARK_VERTEX);
            check(&s, &n, &sx, &nx, "first mark");
            // marked ⧺ unmarked: B's spans were never repaired before
            s.concat(sx[0], sx[6]);
            n.concat(nx[0], nx[6]);
            check(&s, &n, &sx, &nx, "marked++unmarked");
            // split the mark back out and re-concat the other way around
            s.split_before(sx[6]);
            n.split_before(nx[6]);
            check(&s, &n, &sx, &nx, "split at old boundary");
            s.concat(sx[6], sx[0]);
            n.concat(nx[6], nx[0]);
            check(&s, &n, &sx, &nx, "unmarked++marked");
            // split right of the mark: the mark stays in the left part
            s.split_before(sx[4]);
            n.split_before(nx[4]);
            check(&s, &n, &sx, &nx, "split right of mark");
            // clear the only mark: aggregates must drain to zero everywhere
            s.set_marks(sx[3], 0);
            n.set_marks(nx[3], 0);
            check(&s, &n, &sx, &nx, "cleared");
            // a *different* sequence marked next (edge kind this time)
            s.set_marks(sx[8], MARK_EDGE);
            n.set_marks(nx[8], MARK_EDGE);
            check(&s, &n, &sx, &nx, "re-marked elsewhere");
        }

        for seed in [1u64, 7, 42, 1234] {
            run::<SkipSeq>(seed);
            run::<TreapSeq>(seed);
        }
    }

    /// Forest-level mark plumbing: vertex and edge marks survive link/cut
    /// churn and the marked searches resolve back to vertices/edges.
    #[test]
    fn forest_marks_follow_links_and_cuts() {
        let mut f = SkipForest::new(5);
        let vs: Vec<_> = (0..8).map(|_| f.add_vertex()).collect();
        for w in vs.windows(2) {
            assert!(f.link(w[0], w[1]));
        }
        assert_eq!(f.find_marked_vertex(vs[0]), None);
        assert_eq!(f.find_marked_edge(vs[0]), None);
        f.set_vertex_mark(vs[5], true);
        f.set_edge_mark(vs[2], vs[3], true);
        assert!(f.vertex_mark(vs[5]));
        assert_eq!(f.find_marked_vertex(vs[0]), Some(vs[5]));
        assert_eq!(f.find_marked_edge(vs[0]), Some((vs[2], vs[3])));
        // cut between the marks: each side sees only its own mark
        assert!(f.cut(vs[3], vs[4]));
        assert_eq!(f.find_marked_vertex(vs[0]), None);
        assert_eq!(f.find_marked_edge(vs[0]), Some((vs[2], vs[3])));
        assert_eq!(f.find_marked_vertex(vs[7]), Some(vs[5]));
        assert_eq!(f.find_marked_edge(vs[7]), None);
        // relink: the tree sees both again; clearing hides them
        assert!(f.link(vs[3], vs[4]));
        assert_eq!(f.find_marked_vertex(vs[0]), Some(vs[5]));
        f.set_vertex_mark(vs[5], false);
        f.set_edge_mark(vs[2], vs[3], false);
        assert_eq!(f.find_marked_vertex(vs[0]), None);
        assert_eq!(f.find_marked_edge(vs[0]), None);
    }

    /// Mirrored-id lifecycle: `ensure_vertex`/`retire_vertex` manage
    /// externally allocated ids without touching the free list.
    #[test]
    fn ensure_and_retire_mirror_vertices() {
        let mut f = TreapForest::new(11);
        f.ensure_vertex(4);
        f.ensure_vertex(1);
        f.ensure_vertex(4); // no-op
        assert!(f.has_vertex(4) && f.has_vertex(1) && !f.has_vertex(0));
        assert_eq!(f.live_vertex_count(), 2);
        assert!(f.link(1, 4));
        assert!(f.connected(1, 4));
        assert!(f.cut(1, 4));
        f.retire_vertex(4);
        f.retire_vertex(1);
        assert_eq!(f.live_vertex_count(), 0);
        assert_eq!(f.seq.live_nodes(), 0);
    }

    #[test]
    fn large_path_and_star() {
        for backend in 0..2 {
            let mut f: Box<dyn Forest> = if backend == 0 {
                Box::new(TreapForest::new(9))
            } else {
                Box::new(SkipForest::new(9))
            };
            let n = 2000;
            let vs: Vec<_> = (0..n).map(|_| f.add_vertex()).collect();
            // path
            for i in 1..n {
                assert!(f.link(vs[i - 1], vs[i]));
            }
            assert_eq!(f.component_size(vs[0]), n);
            assert!(f.connected(vs[0], vs[n - 1]));
            // cut the middle
            assert!(f.cut(vs[n / 2 - 1], vs[n / 2]));
            assert!(!f.connected(vs[0], vs[n - 1]));
            assert_eq!(f.component_size(vs[0]), n / 2);
            assert_eq!(f.component_size(vs[n - 1]), n / 2);
        }
    }
}
