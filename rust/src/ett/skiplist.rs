//! Skip-list-backed sequence: the Tseng–Dhulipala–Blelloch '19 Euler tour
//! realization the paper adopts.
//!
//! Each sequence is a linear, *indexable* skip list headed by a full-height
//! sentinel. Every forward link stores its **width** (number of level-0
//! steps it spans), which makes sequence length an `O(log n)` walk and lets
//! splits repair the widths of boundary-crossing links without rescans.
//!
//! * `seq_id(x)`       — walk up-left from `x` to the sentinel (`O(log n)`
//!                       expected: each leftward step at level ℓ lands on a
//!                       taller node with prob. ½).
//! * `split_before(x)` — the same walk records, per level, the nearest
//!                       left anchor of height > ℓ; exactly those links
//!                       cross the boundary and are re-pointed at a fresh
//!                       sentinel with recomputed widths.
//! * `concat(a, b)`    — a top-down right walk from A's sentinel finds the
//!                       per-level tails; B's sentinel is spliced out.
//!
//! Mark aggregates: every forward link also carries the OR of node marks
//! over its span (the node plus everything up to its successor at that
//! level), so the per-level spans partition the sequence and
//! `seq_marks`/`find_marked` run along the same walks as ids and widths.
//! Split/concat repair exactly the boundary spans, bottom-up (`O(log n)`);
//! a mark-free sequence (`any_marks == false`) skips the repair entirely.

use crate::util::rng::Rng;

use super::{MarkSet, Node, SeedableSequence, Sequence, NIL};

/// Maximum tower height (supports sequences of ~2²⁶ elements; tours are
/// 3v−2 elements so this covers ~2·10⁷ vertices per tree).
const MAX_H: usize = 26;

#[derive(Clone, Copy)]
struct Lvl {
    prev: Node,
    next: Node,
    /// level-0 steps spanned by the `next` link (0 when next == NIL).
    width: u32,
    /// OR of node marks over this link's **span**: the node itself plus
    /// every element strictly between it and its level-ℓ successor (to the
    /// end of the sequence when `next == NIL`). Per level, the spans of
    /// the nodes present at that level partition the sequence, so the
    /// sequence aggregate is the OR along the sentinel's top-level chain.
    agg: MarkSet,
}

const EMPTY_LVL: Lvl = Lvl { prev: NIL, next: NIL, width: 0, agg: 0 };

/// Node header; the tower's `h` levels live contiguously in the arena at
/// `[base, base + h)`. Flat storage removes a pointer indirection per level
/// access and keeps towers cache-resident (the up-left walk is the hottest
/// loop in the whole system — see EXPERIMENTS.md §Perf).
#[derive(Clone, Copy)]
struct SNode {
    base: u32,
    h: u8,
    sentinel: bool,
    /// node-local marks (aggregated into the tower spans above)
    marks: MarkSet,
}

pub struct SkipSeq {
    n: Vec<SNode>,
    /// tower arena
    lvs: Vec<Lvl>,
    /// reusable node ids by tower height
    free_by_h: Vec<Vec<Node>>,
    rng: Rng,
    live: usize,
    /// false until the first nonzero mark: while unset, every span
    /// aggregate is trivially 0 and split/concat skip the repair pass, so
    /// mark-free users (the flat connectivity modes) pay nothing.
    any_marks: bool,
}

impl SkipSeq {
    pub fn new(seed: u64) -> Self {
        SkipSeq {
            n: Vec::new(),
            lvs: Vec::new(),
            free_by_h: vec![Vec::new(); MAX_H + 1],
            rng: Rng::new(seed),
            live: 0,
            any_marks: false,
        }
    }

    #[inline]
    fn h(&self, x: Node) -> usize {
        self.n[x as usize].h as usize
    }

    /// Level ℓ of node x (immutable).
    #[inline]
    fn lv(&self, x: Node, l: usize) -> Lvl {
        let nd = self.n[x as usize];
        debug_assert!(l < nd.h as usize);
        self.lvs[nd.base as usize + l]
    }

    /// Level ℓ of node x (mutable).
    #[inline]
    fn lv_mut(&mut self, x: Node, l: usize) -> &mut Lvl {
        let nd = self.n[x as usize];
        debug_assert!(l < nd.h as usize);
        &mut self.lvs[nd.base as usize + l]
    }

    fn alloc(&mut self, height: usize, sentinel: bool) -> Node {
        if let Some(x) = self.free_by_h[height].pop() {
            let base = self.n[x as usize].base as usize;
            self.lvs[base..base + height].fill(EMPTY_LVL);
            self.n[x as usize].sentinel = sentinel;
            self.n[x as usize].marks = 0;
            return x;
        }
        let base = self.lvs.len() as u32;
        self.lvs.extend(std::iter::repeat(EMPTY_LVL).take(height));
        self.n.push(SNode { base, h: height as u8, sentinel, marks: 0 });
        (self.n.len() - 1) as Node
    }

    /// Recompute the aggregate of x's level-`l` link from the level below
    /// (level 0 reads the node marks). Expected O(1): the level-(l−1)
    /// sub-chain inside one level-l span has geometric length.
    fn recompute_agg(&mut self, x: Node, l: usize) {
        let agg = if l == 0 {
            self.n[x as usize].marks
        } else {
            let stop = self.lv(x, l).next;
            let mut a: MarkSet = 0;
            let mut y = x;
            loop {
                let lvl = self.lv(y, l - 1);
                a |= lvl.agg;
                if lvl.next == stop || lvl.next == NIL {
                    break;
                }
                y = lvl.next;
            }
            a
        };
        self.lv_mut(x, l).agg = agg;
    }

    fn release(&mut self, x: Node) {
        let h = self.n[x as usize].h as usize;
        self.free_by_h[h].push(x);
    }

    /// Up-left walk from element `x`: returns its sentinel, its 1-based
    /// position, and (optionally via `anchors`) per level ℓ the nearest node
    /// of height > ℓ at-or-left of x together with `pos(x) − pos(anchor)`.
    fn walk_up_left(
        &self,
        x: Node,
        mut anchors: Option<&mut [(Node, u32); MAX_H]>,
    ) -> (Node, u32) {
        let mut cur = x;
        let mut delta = 0u32; // pos(x) - pos(cur)
        let mut l = 0usize;
        loop {
            let nd = self.n[cur as usize];
            if let Some(a) = anchors.as_deref_mut() {
                while l < nd.h as usize {
                    a[l] = (cur, delta);
                    l += 1;
                }
            }
            if nd.sentinel {
                return (cur, delta);
            }
            let top = nd.h as usize - 1;
            let q = self.lvs[nd.base as usize + top].prev;
            debug_assert_ne!(q, NIL, "non-sentinel node missing prev at top level");
            let qn = self.n[q as usize];
            delta += self.lvs[qn.base as usize + top].width;
            cur = q;
        }
    }

    /// Down-right walk from a sentinel: per-level tail nodes and their
    /// positions, plus the sequence length.
    fn tails(&self, sentinel: Node) -> ([(Node, u32); MAX_H], u32) {
        let mut out = [(sentinel, 0u32); MAX_H];
        let mut cur = sentinel;
        let mut pos = 0u32;
        for l in (0..MAX_H).rev() {
            loop {
                // cur always has height > l on this walk
                let lvl = self.lv(cur, l);
                if lvl.next == NIL {
                    break;
                }
                pos += lvl.width;
                cur = lvl.next;
            }
            out[l] = (cur, pos);
        }
        (out, pos)
    }

    fn sentinel_of(&self, x: Node) -> Node {
        if self.n[x as usize].sentinel {
            return x;
        }
        self.walk_up_left(x, None).0
    }

    /// 1-based position of element x within its sequence (pos 0 = sentinel).
    #[cfg(test)]
    fn pos(&self, x: Node) -> u32 {
        self.walk_up_left(x, None).1
    }
}

impl Sequence for SkipSeq {
    fn new_node(&mut self) -> Node {
        let height = (1 + self.rng.skip_height(MAX_H as u32 - 1)) as usize;
        let x = self.alloc(height, false);
        let s = self.alloc(MAX_H, true);
        for l in 0..height {
            *self.lv_mut(s, l) = Lvl { prev: NIL, next: x, width: 1, agg: 0 };
            *self.lv_mut(x, l) = Lvl { prev: s, next: NIL, width: 0, agg: 0 };
        }
        self.live += 1;
        x
    }

    fn free_node(&mut self, x: Node) {
        assert!(!self.n[x as usize].sentinel);
        let s = self.lv(x, 0).prev;
        assert!(
            self.n[s as usize].sentinel && self.lv(x, 0).next == NIL,
            "free_node: node {x} is not a singleton sequence"
        );
        self.release(x);
        self.release(s);
        self.live -= 1;
    }

    fn seq_id(&self, x: Node) -> u64 {
        self.sentinel_of(x) as u64
    }

    fn seq_len(&self, x: Node) -> usize {
        let s = self.sentinel_of(x);
        self.tails(s).1 as usize
    }

    fn first_of_seq(&self, x: Node) -> Node {
        let s = self.sentinel_of(x);
        let f = self.lv(s, 0).next;
        debug_assert_ne!(f, NIL, "externally visible sequences are non-empty");
        f
    }

    fn prev(&self, x: Node) -> Option<Node> {
        let p = self.lv(x, 0).prev;
        if p == NIL || self.n[p as usize].sentinel {
            None
        } else {
            Some(p)
        }
    }

    fn next(&self, x: Node) -> Option<Node> {
        let nx = self.lv(x, 0).next;
        if nx == NIL {
            None
        } else {
            Some(nx)
        }
    }

    fn split_before(&mut self, x: Node) {
        let p0 = self.lv(x, 0).prev;
        if self.n[p0 as usize].sentinel {
            return; // already first
        }
        let mut anchors = [(NIL, 0u32); MAX_H];
        let (_old_sent, pos_x) = self.walk_up_left(x, Some(&mut anchors));
        let s2 = self.alloc(MAX_H, true);
        let hx = self.h(x);
        for l in 0..MAX_H {
            if l < hx {
                // boundary link: x.prev[l] -> x
                let p = self.lv(x, l).prev;
                let plv = self.lv_mut(p, l);
                plv.next = NIL;
                plv.width = 0;
                *self.lv_mut(s2, l) = Lvl { prev: NIL, next: x, width: 1, agg: 0 };
                self.lv_mut(x, l).prev = s2;
            } else {
                let (a, da) = anchors[l];
                let alv = self.lv(a, l);
                if alv.next == NIL {
                    continue; // nothing crosses at this level
                }
                let c = alv.next;
                let w = alv.width;
                debug_assert!(w >= da, "anchor link does not reach the boundary");
                // new position of c in the right sequence: (pos(c)-pos_x)+1
                let w_right = w - da + 1;
                let alv = self.lv_mut(a, l);
                alv.next = NIL;
                alv.width = 0;
                *self.lv_mut(s2, l) =
                    Lvl { prev: NIL, next: c, width: w_right, agg: 0 };
                self.lv_mut(c, l).prev = s2;
            }
        }
        if self.any_marks {
            // Repair the span aggregates bottom-up. On the left side only
            // the per-level anchors changed spans (they now run to the end
            // of the left sequence; links below x's height kept their span
            // [p, x) = [p, end-of-left) verbatim). On the right side the
            // fresh sentinel's tower is rebuilt from the levels below.
            for l in 0..MAX_H {
                if l >= hx {
                    self.recompute_agg(anchors[l].0, l);
                }
                self.recompute_agg(s2, l);
            }
        }
        let _ = pos_x;
    }

    fn split_after(&mut self, x: Node) {
        let nx = self.lv(x, 0).next;
        if nx == NIL {
            return; // already last
        }
        self.split_before(nx);
    }

    fn concat(&mut self, a: Node, b: Node) {
        let sa = self.sentinel_of(a);
        let sb = self.sentinel_of(b);
        assert_ne!(sa, sb, "concat within one sequence");
        let (tails, len_a) = self.tails(sa);
        for l in 0..MAX_H {
            let blv = self.lv(sb, l);
            if blv.next == NIL {
                continue;
            }
            let (f, wb) = (blv.next, blv.width);
            let (t, pt) = tails[l];
            let tlv = self.lv_mut(t, l);
            tlv.next = f;
            tlv.width = (len_a - pt) + wb;
            self.lv_mut(f, l).prev = t;
        }
        self.release(sb);
        if self.any_marks {
            // Every per-level tail of A changed span (it now extends into
            // B, whether or not a link was spliced at that level); B-side
            // spans are untouched. Bottom-up, as each level reads the one
            // below.
            for l in 0..MAX_H {
                self.recompute_agg(tails[l].0, l);
            }
        }
    }

    fn live_nodes(&self) -> usize {
        self.live
    }

    fn marks(&self, x: Node) -> MarkSet {
        self.n[x as usize].marks
    }

    fn set_marks(&mut self, x: Node, marks: MarkSet) {
        debug_assert!(!self.n[x as usize].sentinel);
        if self.n[x as usize].marks == marks {
            return;
        }
        self.n[x as usize].marks = marks;
        if marks != 0 {
            self.any_marks = true;
        }
        if !self.any_marks {
            return;
        }
        // the spans containing x are exactly the per-level anchors of the
        // up-left walk; repair them bottom-up
        let mut anchors = [(NIL, 0u32); MAX_H];
        self.walk_up_left(x, Some(&mut anchors));
        for l in 0..MAX_H {
            self.recompute_agg(anchors[l].0, l);
        }
    }

    fn seq_marks(&self, x: Node) -> MarkSet {
        let s = self.sentinel_of(x);
        let mut a: MarkSet = 0;
        let mut y = s;
        loop {
            let lvl = self.lv(y, MAX_H - 1);
            a |= lvl.agg;
            if lvl.next == NIL {
                return a;
            }
            y = lvl.next;
        }
    }

    fn find_marked(&self, x: Node, kind: MarkSet) -> Option<Node> {
        let mut cur = self.sentinel_of(x);
        let mut l = MAX_H - 1;
        loop {
            // scan right for the first span whose aggregate carries `kind`
            while cur != NIL && self.lv(cur, l).agg & kind == 0 {
                cur = self.lv(cur, l).next;
            }
            if cur == NIL {
                return None; // only reachable from the top level
            }
            // `cur` opens its span, so if it is marked it is the first hit
            // (the sentinel itself never carries marks)
            if self.n[cur as usize].marks & kind != 0 {
                return Some(cur);
            }
            debug_assert!(l > 0, "level-0 aggregate equals the node marks");
            l -= 1;
            // descend: the hit lies inside cur's span, so the level-(l−1)
            // rescan from cur stops before leaving it
        }
    }
}

impl SeedableSequence for SkipSeq {
    fn from_seed(seed: u64) -> Self {
        SkipSeq::new(seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{run_prop, Gen};

    #[test]
    fn skiplist_sequence_matches_vec_oracle() {
        run_prop("skiplist seq oracle", 80, |g: &mut Gen| {
            let mut s = SkipSeq::new(g.rng.next_u64());
            crate::ett::testutil::sequence_oracle_scenario(&mut s, g);
        });
    }

    #[test]
    fn positions_and_lengths() {
        let mut s = SkipSeq::new(7);
        let nodes: Vec<Node> = (0..100).map(|_| s.new_node()).collect();
        for w in nodes.windows(2) {
            s.concat(w[0], w[1]);
        }
        assert_eq!(s.seq_len(nodes[50]), 100);
        for (i, &x) in nodes.iter().enumerate() {
            assert_eq!(s.pos(x), i as u32 + 1, "pos of element {i}");
        }
        // split in the middle and re-check
        s.split_before(nodes[40]);
        assert_eq!(s.seq_len(nodes[0]), 40);
        assert_eq!(s.seq_len(nodes[99]), 60);
        assert_eq!(s.pos(nodes[40]), 1);
        assert_eq!(s.pos(nodes[99]), 60);
        assert_ne!(s.seq_id(nodes[39]), s.seq_id(nodes[40]));
    }

    #[test]
    fn singleton_lifecycle() {
        let mut s = SkipSeq::new(1);
        let a = s.new_node();
        assert_eq!(s.seq_len(a), 1);
        assert_eq!(s.prev(a), None);
        assert_eq!(s.next(a), None);
        s.split_before(a);
        s.split_after(a);
        assert_eq!(s.first_of_seq(a), a);
        s.free_node(a);
        assert_eq!(s.live_nodes(), 0);
    }

    #[test]
    fn heights_are_geometric() {
        let mut s = SkipSeq::new(42);
        let mut tall = 0;
        let n = 4000;
        for _ in 0..n {
            let x = s.new_node();
            if s.h(x) >= 2 {
                tall += 1;
            }
        }
        let frac = tall as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.05, "P(h>=2)={frac}");
    }
}
