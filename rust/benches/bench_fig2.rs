//! Bench target for **Figure 2** (blobs dataset):
//!   (a) cumulative running time per batch;
//!   (b) ARI per batch, random arrival order;
//!   (c) ARI per batch, cluster-by-cluster arrival order.
//!
//! ```bash
//! cargo bench --bench bench_fig2            # all three panels, SCALE=0.05
//! cargo bench --bench bench_fig2 -- b c     # selected panels
//! FULL=1 cargo bench --bench bench_fig2     # paper-size (n=200k)
//! EXACT=1 cargo bench --bench bench_fig2    # include the O(n²) baseline
//! ```
//!
//! Paper reference: (a) DyDBSCAN lowest curve, EMZ ~3x, sklearn ~7x at
//! n=200k; (b) all ARI ≈ 1 under random order; (c) EMZFixedCore collapses
//! while DyDBSCAN/EMZ stay ≈ 1.

use dyn_dbscan::bench_harness::export_json;
use dyn_dbscan::experiments::env_scale;
use dyn_dbscan::experiments::fig2::{run_fig2, Panel};

fn main() {
    let args: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with('-'))
        .collect();
    let panels: Vec<Panel> = if args.is_empty() {
        vec![Panel::Time, Panel::AriRandom, Panel::AriClustered]
    } else {
        args.iter().filter_map(|a| Panel::from_name(a)).collect()
    };
    let scale = env_scale();
    let include_exact = std::env::var("EXACT").map(|v| v == "1").unwrap_or(false)
        || scale <= 0.05;
    for panel in panels {
        let series = run_fig2(panel, scale, 42, include_exact).expect("fig2");
        series.print();
        export_json(&series.to_json());
    }
}
