//! Bench target for **Table 2**: Time / ARI / NMI per dataset for
//! DyDBSCAN, EMZ (re-run per batch) and the Sklearn-equivalent exact
//! DBSCAN.
//!
//! ```bash
//! cargo bench --bench bench_table2              # SCALE=0.05, RUNS=3
//! FULL=1 RUNS=10 cargo bench --bench bench_table2   # paper-size run
//! SCALE=0.2 cargo bench --bench bench_table2 -- letter blobs
//! ```
//!
//! Paper reference (Table 2, seconds / ARI / NMI): e.g. blobs —
//! DyDBSCAN 84.39s/1.00/0.99, EMZ 241.96s/1.00/1.00, SKLEARN
//! 621.43s/0.98/0.97. Absolute times differ (Rust vs the authors' Python,
//! different CPU); the *ordering and ratios* are the reproduction target.

use dyn_dbscan::bench_harness::export_json;
use dyn_dbscan::coordinator::driver::EngineKind;
use dyn_dbscan::data::synth::PaperDataset;
use dyn_dbscan::experiments::table2::run_table2;
use dyn_dbscan::experiments::{env_runs, env_scale};

fn main() {
    let args: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with('-'))
        .collect();
    let datasets: Vec<PaperDataset> = if args.is_empty() {
        PaperDataset::ALL.to_vec()
    } else {
        args.iter()
            .filter_map(|a| PaperDataset::from_name(a))
            .collect()
    };
    let scale = env_scale();
    let runs = env_runs();
    eprintln!(
        "table2: datasets={:?} scale={scale} runs={runs}",
        datasets.iter().map(|d| d.name()).collect::<Vec<_>>()
    );
    let (table, rows) =
        run_table2(&datasets, scale, runs, EngineKind::Native).expect("table2");
    table.print();
    export_json(&table.to_json());

    // headline ratio check (printed, not asserted): DyDBSCAN vs EMZ
    println!("\nspeedup vs EMZ (paper: 1.05x letter … 13.9x kddcup):");
    for r in &rows {
        let s = r.emz.time.mean() / r.dyn_.time.mean().max(1e-9);
        println!("  {:<14} {s:.2}x", r.dataset.name());
    }
}
