//! Ablation A3: per-operation update cost vs n — the empirical check of
//! Theorem 1's `O(d log³n + log⁴n)` claim, plus the eager-attach extension
//! and repair-mode overhead.
//!
//! For each n the structure is pre-filled with n points, then the marginal
//! cost of 2000 further inserts and 2000 deletes is measured. A polylog
//! bound predicts near-flat per-op times across decades of n (vs the
//! linear growth a per-batch static rebuild exhibits).
//!
//! ```bash
//! cargo bench --bench bench_updates
//! ```

use dyn_dbscan::bench_harness::Table;
use dyn_dbscan::dbscan::{DbscanConfig, DynamicDbscan, PaperConn, RepairConn};
use dyn_dbscan::ett::SkipForest;
use dyn_dbscan::util::rng::Rng;

const DIM: usize = 10;

fn gen_point(rng: &mut Rng) -> Vec<f32> {
    let c = rng.below(10) as f64 * 1.2;
    (0..DIM).map(|_| (c + rng.uniform(-0.6, 0.6)) as f32).collect()
}

struct Probe {
    add_us: f64,
    del_us: f64,
    searches: u64,
    visited: u64,
}

fn probe_mode(n: usize, eager: bool, paper_exact: bool, seed: u64) -> Probe {
    let cfg = DbscanConfig {
        k: 10,
        t: 10,
        eps: 0.75,
        dim: DIM,
        eager_attach: eager,
    };
    macro_rules! run {
        ($db:expr) => {{
            let mut db = $db;
            let mut rng = Rng::new(seed);
            let mut live: Vec<u64> = Vec::with_capacity(n + 4000);
            for _ in 0..n {
                live.push(db.add_point(&gen_point(&mut rng)));
            }
            let probes = 2000;
            let t0 = std::time::Instant::now();
            let mut added = Vec::with_capacity(probes);
            for _ in 0..probes {
                added.push(db.add_point(&gen_point(&mut rng)));
            }
            let add_us = t0.elapsed().as_secs_f64() * 1e6 / probes as f64;
            // delete a random mix of old and new points
            let t0 = std::time::Instant::now();
            for i in 0..probes {
                let p = if i % 2 == 0 {
                    added.pop().unwrap()
                } else {
                    let j = rng.below_usize(live.len());
                    live.swap_remove(j)
                };
                db.delete_point(p);
            }
            let del_us = t0.elapsed().as_secs_f64() * 1e6 / probes as f64;
            let st = db.repair_stats();
            Probe { add_us, del_us, searches: st.searches, visited: st.visited }
        }};
    }
    if paper_exact {
        run!(DynamicDbscan::with_conn(
            cfg,
            seed,
            PaperConn::new(SkipForest::new(seed ^ 1))
        ))
    } else {
        run!(DynamicDbscan::with_conn(
            cfg,
            seed,
            RepairConn::new(SkipForest::new(seed ^ 1))
        ))
    }
}

fn main() {
    let mut table = Table::new(
        "A3: per-op update cost vs n (µs/op; polylog ⇒ near-flat)",
        &[
            "n",
            "add µs",
            "del µs",
            "add µs (eager)",
            "del µs (eager)",
            "add µs (paper-exact)",
            "repl searches",
            "visited/search",
        ],
    );
    let quick = std::env::var("FULL").map(|v| v != "1").unwrap_or(true);
    let sizes: &[usize] = if quick {
        &[1_000, 4_000, 16_000, 64_000]
    } else {
        &[1_000, 4_000, 16_000, 64_000, 200_000]
    };
    for &n in sizes {
        let base = probe_mode(n, false, false, 42);
        let eager = probe_mode(n, true, false, 42);
        let paper = probe_mode(n, false, true, 42);
        let vps = if base.searches > 0 {
            format!("{:.1}", base.visited as f64 / base.searches as f64)
        } else {
            "0".into()
        };
        table.row(vec![
            n.to_string(),
            format!("{:.1}", base.add_us),
            format!("{:.1}", base.del_us),
            format!("{:.1}", eager.add_us),
            format!("{:.1}", eager.del_us),
            format!("{:.1}", paper.add_us),
            base.searches.to_string(),
            vps,
        ]);
    }
    table.print();
    dyn_dbscan::bench_harness::export_json(&table.to_json());
}
