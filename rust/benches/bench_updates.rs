//! Update-path benchmarks.
//!
//! 1. **Ablation A3**: per-operation update cost vs n — the empirical check
//!    of Theorem 1's `O(d log³n + log⁴n)` claim (on the leveled default),
//!    plus the eager-attach extension and the paper-exact comparison. For
//!    each n the structure is pre-filled with n points, then the marginal
//!    cost of 2000 further inserts and 2000 deletes is measured.
//! 2. **Update throughput** (→ `BENCH_updates.json` at the repo root): the
//!    standard streaming-blobs churn workload (k=10, t=10, ε=0.75, n=50k,
//!    20% deletes) through the single-instance per-op path, the batched
//!    `apply_batch` path, and the sharded engine at S ∈ {1, 2, 4, 8} —
//!    ops/sec plus p50/p99 add & delete latency. This file is the perf
//!    trajectory every later PR measures against. The same workload also
//!    runs across the **conn ablation axis** (paper / repair / leveled),
//!    the **façade-overhead axis** (serve vs direct engine) and the
//!    **obs-overhead axis** (live metrics registry vs no-op recorder),
//!    both gated at ≤2% per-op tax at full scale. The **read-path axis**
//!    measures ε-neighborhood and kNN QPS through the snapshot-pinned
//!    ε-cell index vs the retained scan oracle at 50k and 500k live
//!    (≥10× ε speedup gated at full scale) and the index's per-op
//!    maintenance tax (≤3% at full scale). The **skew-stress axis**
//!    drives uniform and hot-spot streams through the placement layer
//!    with resharding off vs auto and records the per-shard load
//!    spread — auto must end with a lower peak load than the frozen
//!    assignment (gated at full scale).
//! 3. **Chain churn** (adversarial, also → `BENCH_updates.json`): a 1-D
//!    line of bucket chains with repeated mid-chain block deletions —
//!    every round genuinely splits the path-shaped component, the worst
//!    case for replacement search. This is where the leveled (HDT)
//!    connectivity earns its keep over `RepairConn`'s
//!    `O(min-component)` walk.
//! 4. **Shard sweep** (insert-only, → `BENCH_shard.json`): kept from the
//!    sharding PR for continuity.
//!
//! ```bash
//! cargo bench --bench bench_updates            # full run
//! cargo bench --bench bench_updates -- --smoke # tiny n, validates JSON
//! ```

use std::time::Instant;

use dyn_dbscan::bench_harness::{repo_root_file, write_json, Table};
use dyn_dbscan::data::blobs::{make_blobs, BlobsConfig};
use dyn_dbscan::data::Dataset;
use dyn_dbscan::dbscan::{Connectivity, DbscanConfig, DynamicDbscan, Op, RepairStats};
use dyn_dbscan::metrics::adjusted_rand_index;
use dyn_dbscan::replica::ReadRouter;
use dyn_dbscan::serve::{ClusterEngine, EngineBuilder};
use dyn_dbscan::shard::{ReshardMode, ShardConfig, ShardedEngine, StitchMode};
use dyn_dbscan::util::json::Json;
use dyn_dbscan::util::rng::Rng;
use dyn_dbscan::util::stats::LatencyHisto;
use rustc_hash::{FxHashMap, FxHashSet};

const DIM: usize = 10;

/// Pre-arena (PR 1) single-instance per-op throughput on the standard
/// churn workload (n=50k), recorded in EXPERIMENTS.md §Perf trajectory —
/// the fixed reference the trajectory's speedup field is computed against.
const PRE_ARENA_SINGLE_OPS_PER_S: f64 = 31_010.0;

/// Budgeted serve-façade per-op tax (wall-time fraction over the direct
/// engine, min-of-reps), enforced at full scale where the measurement is
/// stable.
const FACADE_OVERHEAD_GATE_FULL: f64 = 0.02;
/// Looser backstop for smoke-scale workloads, where single runs are
/// scheduler-jitter-dominated and the fixed stitch-tracking cost weighs
/// more against a tiny structure.
const FACADE_OVERHEAD_GATE_SMOKE: f64 = 0.10;

/// The gate that applies to a façade-overhead measurement at workload
/// size `n` (shared by the recorder and the JSON validator).
fn facade_gate(n: f64) -> f64 {
    if n >= 10_000.0 {
        FACADE_OVERHEAD_GATE_FULL
    } else {
        FACADE_OVERHEAD_GATE_SMOKE
    }
}

fn gen_point(rng: &mut Rng) -> Vec<f32> {
    let c = rng.below(10) as f64 * 1.2;
    (0..DIM).map(|_| (c + rng.uniform(-0.6, 0.6)) as f32).collect()
}

struct Probe {
    add_us: f64,
    del_us: f64,
    searches: u64,
    visited: u64,
}

fn probe_mode(n: usize, eager: bool, paper_exact: bool, seed: u64) -> Probe {
    let cfg = DbscanConfig {
        k: 10,
        t: 10,
        eps: 0.75,
        dim: DIM,
        eager_attach: eager,
    };
    macro_rules! run {
        ($db:expr) => {{
            let mut db = $db;
            let mut rng = Rng::new(seed);
            let mut live: Vec<u64> = Vec::with_capacity(n + 4000);
            for _ in 0..n {
                live.push(db.add_point(&gen_point(&mut rng)));
            }
            let probes = 2000;
            let t0 = std::time::Instant::now();
            let mut added = Vec::with_capacity(probes);
            for _ in 0..probes {
                added.push(db.add_point(&gen_point(&mut rng)));
            }
            let add_us = t0.elapsed().as_secs_f64() * 1e6 / probes as f64;
            // delete a random mix of old and new points
            let t0 = std::time::Instant::now();
            for i in 0..probes {
                let p = if i % 2 == 0 {
                    added.pop().unwrap()
                } else {
                    let j = rng.below_usize(live.len());
                    live.swap_remove(j)
                };
                db.delete_point(p);
            }
            let del_us = t0.elapsed().as_secs_f64() * 1e6 / probes as f64;
            let st = db.repair_stats();
            Probe { add_us, del_us, searches: st.searches, visited: st.visited }
        }};
    }
    if paper_exact {
        run!(DynamicDbscan::paper_exact(cfg, seed))
    } else {
        run!(DynamicDbscan::new(cfg, seed))
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    if smoke {
        // tiny end-to-end pass: runs the throughput bench and validates the
        // JSON artifact it writes (the CI gate for the perf trajectory),
        // plus the shards=1 bypass parity gate. Writes to a scratch path so
        // a local smoke run never clobbers the committed full-scale
        // BENCH_updates.json.
        let path = std::env::temp_dir().join("BENCH_updates.smoke.json");
        let publish = (&[400usize, 1_200][..], 5, 80);
        update_throughput(1_500, &[1, 2], (800, 4), publish, &path);
        validate_updates_json(&path);
        assert_shards1_parity();
        println!("smoke OK: {} is valid", path.display());
        return;
    }

    let mut table = Table::new(
        "A3: per-op update cost vs n (µs/op; polylog ⇒ near-flat)",
        &[
            "n",
            "add µs",
            "del µs",
            "add µs (eager)",
            "del µs (eager)",
            "add µs (paper-exact)",
            "repl searches",
            "visited/search",
        ],
    );
    let quick = std::env::var("FULL").map(|v| v != "1").unwrap_or(true);
    let sizes: &[usize] = if quick {
        &[1_000, 4_000, 16_000, 64_000]
    } else {
        &[1_000, 4_000, 16_000, 64_000, 200_000]
    };
    for &n in sizes {
        let base = probe_mode(n, false, false, 42);
        let eager = probe_mode(n, true, false, 42);
        let paper = probe_mode(n, false, true, 42);
        let vps = if base.searches > 0 {
            format!("{:.1}", base.visited as f64 / base.searches as f64)
        } else {
            "0".into()
        };
        table.row(vec![
            n.to_string(),
            format!("{:.1}", base.add_us),
            format!("{:.1}", base.del_us),
            format!("{:.1}", eager.add_us),
            format!("{:.1}", eager.del_us),
            format!("{:.1}", paper.add_us),
            base.searches.to_string(),
            vps,
        ]);
    }
    table.print();
    dyn_dbscan::bench_harness::export_json(&table.to_json());

    let n = if quick { 50_000 } else { 200_000 };
    let chain = if quick { (50_000, 150) } else { (200_000, 150) };
    // publish-latency axis always spans 50k→500k live points: delta
    // publishes must stay flat while the full rebuild grows linearly
    // (the acceptance gate of the delta-snapshot PR)
    let publish = (&[50_000usize, 200_000, 500_000][..], 40, 2_000);
    update_throughput(
        n,
        &[1, 2, 4, 8],
        chain,
        publish,
        &repo_root_file("BENCH_updates.json"),
    );
    shard_sweep(n);
}

/// shards=1 bypass parity gate: the inline single-shard engine must
/// reproduce the single-instance clustering exactly (same seed, same
/// hashing, no ghosts) — the regression this PR fixes was S=1 paying
/// pipeline tax for identical output.
fn assert_shards1_parity() {
    let ds = make_blobs(
        &BlobsConfig {
            n: 800,
            dim: 4,
            clusters: 4,
            std: 0.3,
            center_box: 20.0,
            weights: vec![],
        },
        3,
    );
    let cfg = DbscanConfig { k: 8, t: 8, eps: 0.75, dim: 4, ..Default::default() };
    let mut db = DynamicDbscan::new(cfg.clone(), 42);
    let ids: Vec<u64> = (0..ds.n()).map(|i| db.add_point(ds.point(i))).collect();
    let single = db.labels_for(&ids);
    let mut eng = ShardedEngine::new(ShardConfig::new(cfg, 1, 42));
    for i in 0..ds.n() {
        eng.insert(i as u64, ds.point(i));
    }
    let out = eng.finish();
    assert_eq!(out.stats.ghost_inserts, 0, "S=1 must not replicate");
    let sharded: Vec<i64> = (0..ds.n() as u64)
        .map(|e| out.snapshot.cluster_of(e).expect("live ext labeled"))
        .collect();
    let ari = adjusted_rand_index(&single, &sharded);
    assert!((ari - 1.0).abs() < 1e-9, "shards=1 parity broken: ARI {ari}");
    println!("smoke OK: shards=1 inline path matches single instance (ARI {ari:.3})");
}

// ---------------------------------------------------------------------
// update throughput: the standard churn workload → BENCH_updates.json
// ---------------------------------------------------------------------

/// One op of the churn workload; `ext` is the dataset row.
#[derive(Clone, Copy, Debug)]
enum WlOp {
    Insert(u64),
    Delete(u64),
}

/// Streaming-blobs churn: insert every dataset row once, interleaving
/// deletes of uniformly random live points so that `delete_frac` of all
/// ops are deletes. Deterministic in the seed.
fn build_workload(n: usize, delete_frac: f64, seed: u64) -> (Dataset, Vec<WlOp>) {
    let ds = make_blobs(
        &BlobsConfig {
            n,
            dim: DIM,
            clusters: 24,
            std: 0.3,
            center_box: 60.0,
            weights: vec![],
        },
        seed,
    );
    let mut rng = Rng::new(seed ^ 0x51C);
    let mut ops = Vec::new();
    let mut live: Vec<u64> = Vec::new();
    let mut next_row = 0usize;
    while next_row < n {
        if !live.is_empty() && rng.coin(delete_frac) {
            let i = rng.below_usize(live.len());
            ops.push(WlOp::Delete(live.swap_remove(i)));
        } else {
            ops.push(WlOp::Insert(next_row as u64));
            live.push(next_row as u64);
            next_row += 1;
        }
    }
    (ds, ops)
}

struct SingleRun {
    wall_s: f64,
    add: LatencyHisto,
    del: LatencyHisto,
    conn: RepairStats,
}

/// Per-op path: one `DynamicDbscan` (any connectivity mode), one call per
/// op.
fn run_single<C: Connectivity>(
    mut db: DynamicDbscan<C>,
    ds: &Dataset,
    ops: &[WlOp],
) -> SingleRun {
    let mut ext_map: FxHashMap<u64, u64> = FxHashMap::default();
    let mut add = LatencyHisto::new();
    let mut del = LatencyHisto::new();
    let t0 = Instant::now();
    for op in ops {
        match *op {
            WlOp::Insert(ext) => {
                let o0 = Instant::now();
                let pid = db.add_point(ds.point(ext as usize));
                add.record(o0.elapsed().as_nanos() as u64);
                ext_map.insert(ext, pid);
            }
            WlOp::Delete(ext) => {
                let pid = ext_map.remove(&ext).expect("workload delete of dead ext");
                let o0 = Instant::now();
                db.delete_point(pid);
                del.record(o0.elapsed().as_nanos() as u64);
            }
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    std::hint::black_box(db.num_core_points());
    SingleRun { wall_s, add, del, conn: db.repair_stats() }
}

/// Batched path: the same op stream through `apply_batch` in chunks. A
/// delete of a point added in the still-pending chunk flushes first (its
/// pid is unknown until the batch applies).
fn run_single_batched(
    ds: &Dataset,
    ops: &[WlOp],
    cfg: &DbscanConfig,
    seed: u64,
    batch: usize,
) -> f64 {
    let mut db = DynamicDbscan::new(cfg.clone(), seed);
    let mut ext_map: FxHashMap<u64, u64> = FxHashMap::default();
    let mut pending: Vec<Op> = Vec::with_capacity(batch);
    let mut pending_exts: Vec<u64> = Vec::with_capacity(batch);
    let mut in_pending: FxHashSet<u64> = FxHashSet::default();
    let t0 = Instant::now();
    macro_rules! flush {
        () => {{
            let ids = db.apply_batch(&pending);
            debug_assert_eq!(ids.len(), pending_exts.len());
            for (&ext, pid) in pending_exts.iter().zip(ids) {
                ext_map.insert(ext, pid);
            }
            pending.clear();
            pending_exts.clear();
            in_pending.clear();
        }};
    }
    for op in ops {
        match *op {
            WlOp::Insert(ext) => {
                pending.push(Op::Add(ds.point(ext as usize)));
                pending_exts.push(ext);
                in_pending.insert(ext);
            }
            WlOp::Delete(ext) => {
                if in_pending.contains(&ext) {
                    flush!();
                }
                let pid = *ext_map.get(&ext).expect("workload delete of dead ext");
                ext_map.remove(&ext);
                pending.push(Op::Delete(pid));
            }
        }
        if pending.len() >= batch {
            flush!();
        }
    }
    flush!();
    let wall_s = t0.elapsed().as_secs_f64();
    std::hint::black_box(db.num_core_points());
    wall_s
}

/// Append a latency histogram's p50/p99/mean under the given field names
/// (one shared helper so every JSON section stays schema-consistent).
fn push_histo_fields(
    fields: &mut Vec<(&'static str, Json)>,
    names: [&'static str; 3],
    h: &LatencyHisto,
) {
    let [p50, p99, mean] = names;
    fields.push((p50, Json::num(h.quantile(0.5) as f64)));
    fields.push((p99, Json::num(h.quantile(0.99) as f64)));
    fields.push((mean, Json::num(h.mean())));
}

const ADD_HISTO: [&str; 3] = ["add_p50_ns", "add_p99_ns", "add_mean_ns"];
const DEL_HISTO: [&str; 3] = ["delete_p50_ns", "delete_p99_ns", "delete_mean_ns"];

// ---------------------------------------------------------------------
// façade overhead: serve vs direct engine on the identical workload
// ---------------------------------------------------------------------

/// Measure the serving façade's per-op tax: the same churn workload
/// through the direct structure (`run_single`'s ext map only) and
/// through `serve::EngineBuilder`'s single backend (ext↔pid maps, CoW
/// coordinate store, stitch-change tracking). Paths alternate across
/// `reps` rounds and the per-path minimum is the noise-robust estimate.
/// Returns `(direct_ops_s, facade_ops_s, overhead_frac)`.
fn facade_overhead(n: usize, reps: usize) -> (f64, f64, f64) {
    let cfg = DbscanConfig { k: 10, t: 10, eps: 0.75, dim: DIM, ..Default::default() };
    let (ds, ops) = build_workload(n, 0.2, 13);
    let total_ops = ops.len() as f64;
    let mut direct_best = f64::MAX;
    let mut facade_best = f64::MAX;
    for _ in 0..reps {
        let run = run_single(DynamicDbscan::new(cfg.clone(), 42), &ds, &ops);
        direct_best = direct_best.min(run.wall_s);

        let mut eng = EngineBuilder::from_config(cfg.clone())
            .seed(42)
            .build()
            .expect("façade engine");
        let t0 = Instant::now();
        for op in &ops {
            match *op {
                WlOp::Insert(ext) => eng.upsert(ext, ds.point(ext as usize)),
                WlOp::Delete(ext) => eng.remove(ext),
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let view = eng.publish();
        std::hint::black_box(view.clusters());
        facade_best = facade_best.min(wall);
    }
    let overhead = facade_best / direct_best - 1.0;
    (total_ops / direct_best, total_ops / facade_best, overhead)
}

/// Run the façade-overhead axis, print the comparison and return the
/// JSON section for `BENCH_updates.json`.
fn facade_overhead_section(n: usize, reps: usize) -> Json {
    let (direct_ops_s, facade_ops_s, overhead) = facade_overhead(n, reps);
    let mut table = Table::new(
        "façade overhead: serve single backend vs direct engine (per-op)",
        &["path", "ops/s"],
    );
    table.row(vec!["direct".into(), format!("{direct_ops_s:.0}")]);
    table.row(vec![
        format!("serve façade ({:+.2}%)", overhead * 100.0),
        format!("{facade_ops_s:.0}"),
    ]);
    table.print();
    Json::obj(vec![
        ("n", Json::num(n as f64)),
        ("reps", Json::num(reps as f64)),
        ("direct_ops_per_s", Json::num(direct_ops_s)),
        ("facade_ops_per_s", Json::num(facade_ops_s)),
        ("overhead_frac", Json::num(overhead)),
        ("gate_frac", Json::num(facade_gate(n as f64))),
    ])
}

// ---------------------------------------------------------------------
// obs overhead: live metrics registry vs no-op recorder
// ---------------------------------------------------------------------

/// Measure the observability tax: the identical churn workload through
/// the serve single backend with the metrics registry live
/// (`.metrics(true)`, the default) and with the no-op recorder
/// (`.metrics(false)`). Paths alternate across `reps` rounds,
/// min-of-reps per path. The registry's per-op cost is two `Instant`
/// reads plus striped relaxed atomic increments, so the tax must stay
/// inside the same ≤2% budget as the façade itself. Returns
/// `(on_ops_s, off_ops_s, overhead_frac)`.
fn obs_overhead(n: usize, reps: usize) -> (f64, f64, f64) {
    let cfg = DbscanConfig { k: 10, t: 10, eps: 0.75, dim: DIM, ..Default::default() };
    let (ds, ops) = build_workload(n, 0.2, 17);
    let total_ops = ops.len() as f64;
    let mut on_best = f64::MAX;
    let mut off_best = f64::MAX;
    for _ in 0..reps {
        for metrics in [true, false] {
            let mut eng = EngineBuilder::from_config(cfg.clone())
                .seed(42)
                .metrics(metrics)
                .build()
                .expect("obs-overhead engine");
            let t0 = Instant::now();
            for op in &ops {
                match *op {
                    WlOp::Insert(ext) => eng.upsert(ext, ds.point(ext as usize)),
                    WlOp::Delete(ext) => eng.remove(ext),
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            let view = eng.publish();
            std::hint::black_box(view.clusters());
            if metrics {
                on_best = on_best.min(wall);
            } else {
                off_best = off_best.min(wall);
            }
        }
    }
    let overhead = on_best / off_best - 1.0;
    (total_ops / on_best, total_ops / off_best, overhead)
}

/// Run the obs-overhead axis, print the comparison and return the JSON
/// section for `BENCH_updates.json`.
fn obs_overhead_section(n: usize, reps: usize) -> Json {
    let (on_ops_s, off_ops_s, overhead) = obs_overhead(n, reps);
    let mut table = Table::new(
        "obs overhead: live metrics registry vs no-op recorder (per-op)",
        &["recorder", "ops/s"],
    );
    table.row(vec!["metrics off".into(), format!("{off_ops_s:.0}")]);
    table.row(vec![
        format!("metrics on ({:+.2}%)", overhead * 100.0),
        format!("{on_ops_s:.0}"),
    ]);
    table.print();
    Json::obj(vec![
        ("n", Json::num(n as f64)),
        ("reps", Json::num(reps as f64)),
        ("metrics_on_ops_per_s", Json::num(on_ops_s)),
        ("metrics_off_ops_per_s", Json::num(off_ops_s)),
        ("overhead_frac", Json::num(overhead)),
        ("gate_frac", Json::num(facade_gate(n as f64))),
    ])
}

// ---------------------------------------------------------------------
// durability: steady-state WAL overhead + crash-recovery time
// ---------------------------------------------------------------------

/// Budgeted steady-state WAL tax (wall-time fraction of a persistent
/// engine over persist-off on the identical churn workload, min-of-reps),
/// asserted at full scale.
const WAL_OVERHEAD_GATE_FULL: f64 = 0.05;
/// Smoke backstop: tiny runs are fsync-latency- and jitter-dominated
/// (one publish amortizes its group fsync over very few ops).
const WAL_OVERHEAD_GATE_SMOKE: f64 = 0.50;

/// The gate that applies to a WAL-overhead measurement at workload size
/// `n` (shared by the recorder and the JSON validator).
fn wal_gate(n: f64) -> f64 {
    if n >= 10_000.0 {
        WAL_OVERHEAD_GATE_FULL
    } else {
        WAL_OVERHEAD_GATE_SMOKE
    }
}

/// Fresh persist scratch directory under the system temp root.
fn persist_scratch(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("dyn-dbscan-bench-recovery-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Stream the churn workload through the serve façade (publish every
/// 2000 ops — each publish is a WAL group-fsync barrier on persistent
/// engines) and return the wall time plus the still-open engine.
fn facade_churn_run(
    ds: &Dataset,
    ops: &[WlOp],
    persist: Option<(&std::path::Path, u64)>,
) -> (f64, Box<dyn ClusterEngine>) {
    let mut b = EngineBuilder::new(DIM).seed(42);
    if let Some((dir, every)) = persist {
        b = b.persist(dir).persist_every(every);
    }
    let mut eng = b.build().unwrap();
    let t0 = Instant::now();
    for (i, op) in ops.iter().enumerate() {
        match *op {
            WlOp::Insert(ext) => eng.upsert(ext, ds.point(ext as usize)),
            WlOp::Delete(ext) => eng.remove(ext),
        }
        if (i + 1) % 2000 == 0 {
            eng.publish();
        }
    }
    eng.publish();
    (t0.elapsed().as_secs_f64(), eng)
}

/// Crash a persistent run (`mem::forget` — no flush, no shutdown
/// checkpoint) and time how long an identically-configured `build()`
/// takes to recover. Returns (reopen wall s, replayed record count).
fn timed_recovery(
    ds: &Dataset,
    ops: &[WlOp],
    dir: &std::path::Path,
    checkpoint_every: u64,
) -> (f64, u64) {
    let (_, eng) = facade_churn_run(ds, ops, Some((dir, checkpoint_every)));
    std::mem::forget(eng);
    let t0 = Instant::now();
    let recovered = EngineBuilder::new(DIM)
        .seed(42)
        .persist(dir)
        .build()
        .unwrap();
    let wall_s = t0.elapsed().as_secs_f64();
    let replayed = recovered.metrics().wal.replay_records;
    let _ = recovered.finish();
    let _ = std::fs::remove_dir_all(dir);
    (wall_s, replayed)
}

/// The durability axis: steady-state WAL overhead (persist on vs off on
/// the main workload, min-of-reps) and recovery wall time — cold full-log
/// replay vs checkpoint + WAL-tail — at each live size in `sizes`.
fn recovery_section(
    ds: &Dataset,
    ops: &[WlOp],
    n: usize,
    reps: usize,
    sizes: &[usize],
) -> Json {
    let mut off_best = f64::MAX;
    let mut on_best = f64::MAX;
    for rep in 0..reps {
        let (off_s, eng) = facade_churn_run(ds, ops, None);
        let _ = eng.finish();
        off_best = off_best.min(off_s);
        let dir = persist_scratch(&format!("overhead-{rep}"));
        let (on_s, eng) = facade_churn_run(ds, ops, Some((&dir, 8)));
        let _ = eng.finish();
        let _ = std::fs::remove_dir_all(&dir);
        on_best = on_best.min(on_s);
    }
    let total_ops = ops.len() as f64;
    let overhead = (on_best - off_best) / off_best;
    let mut table = Table::new(
        "durability: WAL overhead (churn, publish every 2000 ops)",
        &["engine", "ops/s"],
    );
    table.row(vec!["persist off".into(), format!("{:.0}", total_ops / off_best)]);
    table.row(vec![
        format!("persist on ({:+.2}%)", overhead * 100.0),
        format!("{:.0}", total_ops / on_best),
    ]);
    table.print();

    let mut rows: Vec<Json> = Vec::new();
    let mut rec_table = Table::new(
        "durability: crash-recovery wall time",
        &["live", "cold replay s", "ckpt+tail s"],
    );
    for &live in sizes {
        let (rds, rops) = build_workload(live, 0.2, 7);
        let cold_dir = persist_scratch(&format!("cold-{live}"));
        // checkpoint cadence pushed out of reach ⇒ recovery replays the
        // full op log
        let (cold_s, cold_records) = timed_recovery(&rds, &rops, &cold_dir, u64::MAX);
        let ckpt_dir = persist_scratch(&format!("ckpt-{live}"));
        let (ckpt_s, ckpt_records) = timed_recovery(&rds, &rops, &ckpt_dir, 8);
        rec_table.row(vec![
            live.to_string(),
            format!("{cold_s:.3}"),
            format!("{ckpt_s:.3}"),
        ]);
        rows.push(Json::obj(vec![
            ("live", Json::num(live as f64)),
            ("cold_replay_s", Json::num(cold_s)),
            ("cold_replay_records", Json::num(cold_records as f64)),
            ("ckpt_tail_replay_s", Json::num(ckpt_s)),
            ("ckpt_tail_replay_records", Json::num(ckpt_records as f64)),
        ]));
    }
    rec_table.print();

    Json::obj(vec![
        ("n", Json::num(n as f64)),
        ("reps", Json::num(reps as f64)),
        ("persist_off_ops_per_s", Json::num(total_ops / off_best)),
        ("persist_on_ops_per_s", Json::num(total_ops / on_best)),
        ("overhead_frac", Json::num(overhead)),
        ("gate_frac", Json::num(wal_gate(n as f64))),
        ("recovery", Json::Arr(rows)),
    ])
}

// ---------------------------------------------------------------------
// read path: snapshot-pinned ε-cell index vs retained scan oracle
// ---------------------------------------------------------------------

/// Budgeted per-op tax of the O(Δ) index maintenance folded into the
/// update path (index on vs `.spatial_index(false)`, min-of-reps),
/// enforced at full scale.
const INDEX_OVERHEAD_GATE_FULL: f64 = 0.03;
/// Smoke backstop: tiny runs are scheduler-jitter-dominated and the
/// fixed cell-table cost weighs more against a tiny structure.
const INDEX_OVERHEAD_GATE_SMOKE: f64 = 0.30;

/// The gate that applies to an index-maintenance measurement at workload
/// size `n` (shared by the recorder and the JSON validator).
fn read_gate(n: f64) -> f64 {
    if n >= 10_000.0 {
        INDEX_OVERHEAD_GATE_FULL
    } else {
        INDEX_OVERHEAD_GATE_SMOKE
    }
}

/// Minimum indexed-over-scan ε-query speedup, asserted only when every
/// live size on the axis is full scale (≥ 50k) — the asymptotic gap
/// (O(points-in-3^d-cells) vs O(n·d)) is unambiguous there.
const EPS_SPEEDUP_GATE_FULL: f64 = 10.0;

/// Deterministic probe set: every stride-th live row (dense and sparse
/// cells alike) plus a few uniform positions (mostly-empty space).
fn read_probes(ds: &Dataset, live: usize, count: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    let stride = (live / count.max(1)).max(1);
    let mut probes: Vec<Vec<f32>> = (0..live)
        .step_by(stride)
        .take(count)
        .map(|i| ds.point(i).to_vec())
        .collect();
    for _ in 0..count / 4 {
        probes.push((0..DIM).map(|_| rng.uniform(-60.0, 60.0) as f32).collect());
    }
    probes
}

/// Time `f` over every probe, `reps` rounds, min-of-reps; returns QPS.
fn time_queries(probes: &[Vec<f32>], reps: usize, mut f: impl FnMut(&[f32])) -> f64 {
    let mut best = f64::MAX;
    for _ in 0..reps {
        let t0 = Instant::now();
        for p in probes {
            f(p);
        }
        best = best.min(t0.elapsed().as_secs_f64());
    }
    probes.len() as f64 / best
}

/// The read-path axis: ε-neighborhood and kNN QPS through the pinned
/// ε-cell index vs the retained scan oracle at each live size in `sizes`,
/// plus the per-op index-maintenance tax on the standard churn workload
/// (index on vs `.spatial_index(false)`, the obs-overhead alternating
/// min-of-reps template). Indexed answers are asserted bit-identical to
/// the oracle's on every probe before any timing starts.
fn read_path_section(sizes: &[usize], n: usize, reps: usize) -> Json {
    let knn_k = 10usize;
    let mut table = Table::new(
        "read path: indexed vs scan QPS (ε-neighborhood, kNN k=10)",
        &["live", "ε idx qps", "ε scan qps", "ε speedup", "kNN idx qps", "kNN scan qps"],
    );
    let mut rows: Vec<Json> = Vec::new();
    for &live in sizes {
        let ds = make_blobs(
            &BlobsConfig {
                n: live,
                dim: DIM,
                clusters: 24,
                std: 0.3,
                center_box: 60.0,
                weights: vec![],
            },
            11,
        );
        let mut eng = EngineBuilder::new(DIM).seed(42).build().unwrap();
        for i in 0..live {
            eng.upsert(i as u64, ds.point(i));
        }
        let view = eng.publish();
        assert!(
            view.has_spatial_index(),
            "read-path bench needs the index on (DIM must stay within \
             IndexPolicy::max_dim)"
        );
        let probes = read_probes(&ds, live, 64, 0xBEEF ^ live as u64);
        // exactness spot check before timing: the indexed path must
        // reproduce the oracle bit-for-bit on every probe
        for p in &probes {
            assert_eq!(
                view.epsilon_neighbors(p),
                view.epsilon_neighbors_scan(p),
                "indexed ε-query diverged from the scan oracle"
            );
            assert_eq!(
                view.k_nearest(p, knn_k),
                view.k_nearest_scan(p, knn_k),
                "indexed kNN diverged from the scan oracle"
            );
        }
        let eps_idx = time_queries(&probes, reps, |p| {
            std::hint::black_box(view.epsilon_neighbors(p));
        });
        let eps_scan = time_queries(&probes, reps, |p| {
            std::hint::black_box(view.epsilon_neighbors_scan(p));
        });
        let knn_idx = time_queries(&probes, reps, |p| {
            std::hint::black_box(view.k_nearest(p, knn_k));
        });
        let knn_scan = time_queries(&probes, reps, |p| {
            std::hint::black_box(view.k_nearest_scan(p, knn_k));
        });
        let _ = eng.finish();
        let eps_speedup = eps_idx / eps_scan;
        table.row(vec![
            live.to_string(),
            format!("{eps_idx:.0}"),
            format!("{eps_scan:.0}"),
            format!("{eps_speedup:.1}x"),
            format!("{knn_idx:.0}"),
            format!("{knn_scan:.0}"),
        ]);
        rows.push(Json::obj(vec![
            ("live", Json::num(live as f64)),
            ("eps_indexed_qps", Json::num(eps_idx)),
            ("eps_scan_qps", Json::num(eps_scan)),
            ("eps_speedup", Json::num(eps_speedup)),
            ("knn_indexed_qps", Json::num(knn_idx)),
            ("knn_scan_qps", Json::num(knn_scan)),
            ("knn_speedup", Json::num(knn_idx / knn_scan)),
        ]));
    }
    table.print();

    // maintenance tax: the identical churn workload with the per-op
    // index folds on vs off, alternating, min-of-reps per path
    let (ds, ops) = build_workload(n, 0.2, 19);
    let total_ops = ops.len() as f64;
    let mut on_best = f64::MAX;
    let mut off_best = f64::MAX;
    for _ in 0..reps {
        for index_on in [true, false] {
            let mut eng = EngineBuilder::new(DIM)
                .seed(42)
                .spatial_index(index_on)
                .build()
                .expect("read-path engine");
            let t0 = Instant::now();
            for op in &ops {
                match *op {
                    WlOp::Insert(ext) => eng.upsert(ext, ds.point(ext as usize)),
                    WlOp::Delete(ext) => eng.remove(ext),
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            let view = eng.publish();
            std::hint::black_box(view.clusters());
            if index_on {
                on_best = on_best.min(wall);
            } else {
                off_best = off_best.min(wall);
            }
        }
    }
    let overhead = on_best / off_best - 1.0;
    let mut tax = Table::new(
        "read path: index maintenance tax (churn per-op, index on vs off)",
        &["index", "ops/s"],
    );
    tax.row(vec!["off".into(), format!("{:.0}", total_ops / off_best)]);
    tax.row(vec![
        format!("on ({:+.2}%)", overhead * 100.0),
        format!("{:.0}", total_ops / on_best),
    ]);
    tax.print();

    Json::obj(vec![
        ("n", Json::num(n as f64)),
        ("reps", Json::num(reps as f64)),
        ("knn_k", Json::num(knn_k as f64)),
        ("eps_speedup_gate_full", Json::num(EPS_SPEEDUP_GATE_FULL)),
        ("sizes", Json::Arr(rows)),
        ("index_on_ops_per_s", Json::num(total_ops / on_best)),
        ("index_off_ops_per_s", Json::num(total_ops / off_best)),
        ("maintenance_overhead_frac", Json::num(overhead)),
        ("maintenance_gate_frac", Json::num(read_gate(n as f64))),
    ])
}

// ---------------------------------------------------------------------
// adversarial chain churn: the replacement-search worst case
// ---------------------------------------------------------------------

struct ChainRun {
    wall_s: f64,
    add: LatencyHisto,
    del: LatencyHisto,
    conn: RepairStats,
}

/// Mid-chain deletion block (points per round); clamped for tiny smoke
/// runs. Shared by the workload and its JSON description.
fn chain_block(n: usize) -> usize {
    16usize.min(n / 4)
}

/// 1-D bucket-chain workload: points at spacing 0.1 with ε = 0.4 (bucket
/// width 0.8) form one long path-shaped component of ~8-point buckets.
/// Each round deletes a mid-chain block of 16 points (width 1.6 > any
/// bucket ⇒ a genuine split, so the replacement search runs to
/// exhaustion) and re-inserts it. `RepairConn` pays `O(component)` per
/// split; the leveled default amortizes to polylog via edge-level pushes.
fn chain_churn<C: Connectivity>(
    mut db: DynamicDbscan<C>,
    n: usize,
    rounds: usize,
    seed: u64,
) -> ChainRun {
    let pts: Vec<f32> = (0..n).map(|i| i as f32 * 0.1).collect();
    let mut ids: Vec<u64> = pts.iter().map(|&x| db.add_point(&[x])).collect();
    let mut rng = Rng::new(seed);
    let block = chain_block(n);
    let mut add = LatencyHisto::new();
    let mut del = LatencyHisto::new();
    let t0 = Instant::now();
    for _ in 0..rounds {
        let start = (n / 4 + rng.below_usize(n / 2)).min(n - block);
        for i in start..start + block {
            let o0 = Instant::now();
            db.delete_point(ids[i]);
            del.record(o0.elapsed().as_nanos() as u64);
        }
        for i in start..start + block {
            let o0 = Instant::now();
            ids[i] = db.add_point(&[pts[i]]);
            add.record(o0.elapsed().as_nanos() as u64);
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    std::hint::black_box(db.num_core_points());
    ChainRun { wall_s, add, del, conn: db.repair_stats() }
}

/// Run the chain-churn workload across the conn ablation axis, print the
/// comparison and return the JSON section for `BENCH_updates.json`.
fn chain_churn_section(n: usize, rounds: usize) -> Json {
    let cfg = DbscanConfig { k: 8, t: 4, eps: 0.4, dim: 1, ..Default::default() };
    let mut table = Table::new(
        "chain churn: mid-chain block deletions (conn ablation)",
        &["conn", "wall s", "del p50/p99 µs", "searches", "visited", "pushes", "levels"],
    );
    let mut modes: Vec<Json> = Vec::new();
    for mode in ["leveled", "repair", "paper"] {
        let run = match mode {
            "leveled" => chain_churn(DynamicDbscan::new(cfg.clone(), 42), n, rounds, 7),
            "repair" => {
                chain_churn(DynamicDbscan::repair_mode(cfg.clone(), 42), n, rounds, 7)
            }
            _ => chain_churn(DynamicDbscan::paper_exact(cfg.clone(), 42), n, rounds, 7),
        };
        table.row(vec![
            mode.into(),
            format!("{:.2}", run.wall_s),
            format!(
                "{:.1}/{:.1}",
                run.del.quantile(0.5) as f64 / 1e3,
                run.del.quantile(0.99) as f64 / 1e3
            ),
            run.conn.searches.to_string(),
            run.conn.visited.to_string(),
            run.conn.pushes.to_string(),
            run.conn.levels.to_string(),
        ]);
        let mut fields = vec![
            ("conn", Json::str(mode)),
            ("wall_s", Json::num(run.wall_s)),
        ];
        push_histo_fields(&mut fields, ADD_HISTO, &run.add);
        push_histo_fields(&mut fields, DEL_HISTO, &run.del);
        fields.push(("searches", Json::num(run.conn.searches as f64)));
        fields.push(("visited", Json::num(run.conn.visited as f64)));
        fields.push(("pushes", Json::num(run.conn.pushes as f64)));
        fields.push(("levels", Json::num(run.conn.levels as f64)));
        modes.push(Json::obj(fields));
    }
    table.print();
    Json::obj(vec![
        (
            "workload",
            Json::obj(vec![
                ("name", Json::str("chain-block-churn")),
                ("n", Json::num(n as f64)),
                ("rounds", Json::num(rounds as f64)),
                ("block", Json::num(chain_block(n) as f64)),
                ("k", Json::num(8.0)),
                ("t", Json::num(4.0)),
                ("eps", Json::num(0.4)),
            ]),
        ),
        ("modes", Json::Arr(modes)),
    ])
}

// ---------------------------------------------------------------------
// snapshot publish latency: delta vs full rebuild, vs live-set size
// ---------------------------------------------------------------------

/// Publish-latency axis: at each live size, build one engine per
/// [`StitchMode`], then measure `publish` over `rounds` rounds of a
/// fixed-size churn batch (`churn` ops, half deletes half inserts, live
/// size constant). `quiesce` barriers before each timing so op
/// application is excluded — what's measured is exactly the
/// snapshot-emission cost: `O(Δ·log²n)` for delta (flat in live points at
/// fixed Δ), `O(n log n)` for the rebuild fallback (linear).
fn snapshot_publish_section(sizes: &[usize], rounds: usize, churn: usize) -> Json {
    let shards = 4usize;
    let mut table = Table::new(
        "snapshot publish: delta vs full rebuild (µs per publish, fixed Δ)",
        &["live", "delta p50", "delta p99", "rebuild p50", "rebuild p99"],
    );
    let mut rows: Vec<Json> = Vec::new();
    for &n in sizes {
        let total = n + rounds * churn.div_ceil(2);
        let ds = make_blobs(
            &BlobsConfig {
                n: total,
                dim: DIM,
                clusters: 24,
                std: 0.3,
                center_box: 60.0,
                weights: vec![],
            },
            7,
        );
        let cfg =
            DbscanConfig { k: 10, t: 10, eps: 0.75, dim: DIM, ..Default::default() };
        let mut histos: Vec<LatencyHisto> = Vec::new();
        for mode in [StitchMode::Delta, StitchMode::FullRebuild] {
            let mut scfg = ShardConfig::new(cfg.clone(), shards, 42);
            scfg.stitch = mode;
            let mut eng = ShardedEngine::new(scfg);
            let mut rng = Rng::new(0x5EED ^ n as u64);
            let mut live: Vec<u64> = Vec::with_capacity(n);
            for i in 0..n {
                eng.insert(i as u64, ds.point(i));
                live.push(i as u64);
                if (i + 1) % 1000 == 0 {
                    eng.flush();
                }
            }
            eng.quiesce();
            eng.publish(); // prime: the first delta report ships full state
            let mut histo = LatencyHisto::new();
            let mut next = n;
            for _ in 0..rounds {
                let half = churn / 2;
                for _ in 0..half {
                    let i = rng.below_usize(live.len());
                    let e = live.swap_remove(i);
                    eng.delete(e);
                }
                for _ in 0..half {
                    eng.insert(next as u64, ds.point(next));
                    live.push(next as u64);
                    next += 1;
                }
                // barrier so the timing below is publication only
                eng.quiesce();
                let t0 = Instant::now();
                let snap = eng.publish();
                histo.record(t0.elapsed().as_nanos() as u64);
                std::hint::black_box(snap.clusters);
            }
            histos.push(histo);
            let _ = eng.finish();
        }
        let (delta, rebuild) = (&histos[0], &histos[1]);
        table.row(vec![
            n.to_string(),
            format!("{:.0}", delta.quantile(0.5) as f64 / 1e3),
            format!("{:.0}", delta.quantile(0.99) as f64 / 1e3),
            format!("{:.0}", rebuild.quantile(0.5) as f64 / 1e3),
            format!("{:.0}", rebuild.quantile(0.99) as f64 / 1e3),
        ]);
        let mut fields = vec![("live", Json::num(n as f64))];
        push_histo_fields(
            &mut fields,
            ["delta_publish_p50_ns", "delta_publish_p99_ns", "delta_publish_mean_ns"],
            delta,
        );
        push_histo_fields(
            &mut fields,
            [
                "rebuild_publish_p50_ns",
                "rebuild_publish_p99_ns",
                "rebuild_publish_mean_ns",
            ],
            rebuild,
        );
        rows.push(Json::obj(fields));
    }
    table.print();
    Json::obj(vec![
        ("shards", Json::num(shards as f64)),
        ("rounds", Json::num(rounds as f64)),
        ("churn_ops", Json::num(churn as f64)),
        ("sizes", Json::Arr(rows)),
    ])
}

/// Run the churn workload on every engine configuration (plus the
/// adversarial chain-churn ablation sized by `chain = (n, rounds)` and
/// the publish-latency axis sized by `publish = (sizes, rounds, churn)`)
/// and write the trajectory record to `out_path` (the repo-root
/// `BENCH_updates.json` in full runs, a scratch file under `--smoke`).
fn update_throughput(
    n: usize,
    shard_counts: &[usize],
    chain: (usize, usize),
    publish: (&[usize], usize, usize),
    out_path: &std::path::Path,
) {
    let cfg = DbscanConfig { k: 10, t: 10, eps: 0.75, dim: DIM, ..Default::default() };
    let delete_frac = 0.2;
    let (ds, ops) = build_workload(n, delete_frac, 7);
    let total_ops = ops.len();
    let deletes = ops.iter().filter(|o| matches!(o, WlOp::Delete(_))).count();

    let mut table = Table::new(
        "update throughput: streaming-blobs churn (20% deletes)",
        &["engine", "wall s", "ops/s", "add p50/p99 µs", "del p50/p99 µs"],
    );

    // single-instance, per-op — once per connectivity mode (the conn
    // ablation axis); "single" rows below refer to the leveled default
    let single = run_single(DynamicDbscan::new(cfg.clone(), 42), &ds, &ops);
    let repair = run_single(DynamicDbscan::repair_mode(cfg.clone(), 42), &ds, &ops);
    let paper = run_single(DynamicDbscan::paper_exact(cfg.clone(), 42), &ds, &ops);
    let single_ops_s = total_ops as f64 / single.wall_s;
    for (name, run) in [
        ("single (leveled)", &single),
        ("single (repair)", &repair),
        ("single (paper)", &paper),
    ] {
        table.row(vec![
            name.into(),
            format!("{:.2}", run.wall_s),
            format!("{:.0}", total_ops as f64 / run.wall_s),
            format!(
                "{:.1}/{:.1}",
                run.add.quantile(0.5) as f64 / 1e3,
                run.add.quantile(0.99) as f64 / 1e3
            ),
            format!(
                "{:.1}/{:.1}",
                run.del.quantile(0.5) as f64 / 1e3,
                run.del.quantile(0.99) as f64 / 1e3
            ),
        ]);
    }

    // single-instance, batched ingestion
    let batch = 512usize;
    let batched_wall = run_single_batched(&ds, &ops, &cfg, 42, batch);
    let batched_ops_s = total_ops as f64 / batched_wall;
    table.row(vec![
        format!("single (apply_batch {batch})"),
        format!("{batched_wall:.2}"),
        format!("{batched_ops_s:.0}"),
        "-".into(),
        "-".into(),
    ]);

    // sharded engine
    let mut shard_rows: Vec<Json> = Vec::new();
    for &shards in shard_counts {
        let scfg = ShardConfig::new(cfg.clone(), shards, 42);
        let mut eng = ShardedEngine::new(scfg);
        let t0 = Instant::now();
        for (i, op) in ops.iter().enumerate() {
            match *op {
                WlOp::Insert(ext) => eng.insert(ext, ds.point(ext as usize)),
                WlOp::Delete(ext) => eng.delete(ext),
            }
            if (i + 1) % 1000 == 0 {
                eng.flush();
            }
        }
        eng.flush();
        let snap = eng.publish(); // barrier: every op applied + stitched
        let wall_s = t0.elapsed().as_secs_f64();
        let out = eng.finish();
        let ops_s = total_ops as f64 / wall_s;
        table.row(vec![
            format!("sharded S={shards}"),
            format!("{wall_s:.2}"),
            format!("{ops_s:.0}"),
            format!(
                "{:.1}/{:.1}",
                out.add_latency.quantile(0.5) as f64 / 1e3,
                out.add_latency.quantile(0.99) as f64 / 1e3
            ),
            format!(
                "{:.1}/{:.1}",
                out.delete_latency.quantile(0.5) as f64 / 1e3,
                out.delete_latency.quantile(0.99) as f64 / 1e3
            ),
        ]);
        let conn = out.conn_stats();
        let mut fields = vec![
            ("shards", Json::num(shards as f64)),
            ("wall_s", Json::num(wall_s)),
            ("ops_per_s", Json::num(ops_s)),
            ("speedup_vs_single", Json::num(single.wall_s / wall_s)),
            ("ghost_ratio", Json::num(out.stats.ghost_ratio())),
            ("clusters", Json::num(snap.clusters as f64)),
            ("conn_searches", Json::num(conn.searches as f64)),
            ("conn_pushes", Json::num(conn.pushes as f64)),
            ("conn_levels", Json::num(conn.levels as f64)),
        ];
        push_histo_fields(&mut fields, ADD_HISTO, &out.add_latency);
        push_histo_fields(&mut fields, DEL_HISTO, &out.delete_latency);
        shard_rows.push(Json::obj(fields));
    }
    table.print();

    let mut single_fields = vec![
        ("wall_s", Json::num(single.wall_s)),
        ("ops_per_s", Json::num(single_ops_s)),
    ];
    push_histo_fields(&mut single_fields, ADD_HISTO, &single.add);
    push_histo_fields(&mut single_fields, DEL_HISTO, &single.del);
    // conn ablation axis on the identical uniform-churn workload
    let mut ablation: Vec<Json> = Vec::new();
    for (mode, run) in [("leveled", &single), ("repair", &repair), ("paper", &paper)] {
        ablation.push(Json::obj(vec![
            ("conn", Json::str(mode)),
            ("wall_s", Json::num(run.wall_s)),
            ("ops_per_s", Json::num(total_ops as f64 / run.wall_s)),
            ("delete_p50_ns", Json::num(run.del.quantile(0.5) as f64)),
            ("delete_p99_ns", Json::num(run.del.quantile(0.99) as f64)),
            ("searches", Json::num(run.conn.searches as f64)),
            ("visited", Json::num(run.conn.visited as f64)),
            ("pushes", Json::num(run.conn.pushes as f64)),
            ("levels", Json::num(run.conn.levels as f64)),
        ]));
    }

    // skew-stress axis: uniform vs hot-spot streams, reshard off vs auto,
    // at the sweep's widest shard count
    let skew_shards = shard_counts.iter().copied().max().unwrap_or(2).max(2);
    let skew_section = skew_stress_section(n, skew_shards);

    let chain_section = chain_churn_section(chain.0, chain.1);
    let publish_section = snapshot_publish_section(publish.0, publish.1, publish.2);
    // more reps at small n: single runs are jitter-dominated there
    let reps = if n < 10_000 { 5 } else { 3 };
    let facade_section = facade_overhead_section(n, reps);
    let obs_section = obs_overhead_section(n, reps);
    // recovery time at the ends of the publish-axis size span (50k/500k
    // live at full scale, tiny stand-ins under --smoke)
    let recovery_sizes = [publish.0[0], *publish.0.last().unwrap()];
    let durability_section = recovery_section(&ds, &ops, n, reps, &recovery_sizes);
    // read-path QPS at the same ends of the size span as recovery —
    // the ≥10× ε-speedup gate applies when both ends are full scale
    let read_section = read_path_section(&recovery_sizes, n, reps);
    // replication axis: leader shipping tax at 0/1/2 followers, replica
    // read fan-out, and incremental-vs-full follower bootstrap
    let repl_section = replication_section(&ds, &ops, n, reps);

    let record = Json::obj(vec![
        ("bench", Json::str("updates_throughput")),
        (
            "workload",
            Json::obj(vec![
                ("name", Json::str("streaming-blobs-churn")),
                ("n", Json::num(n as f64)),
                ("dim", Json::num(DIM as f64)),
                ("k", Json::num(10.0)),
                ("t", Json::num(10.0)),
                ("eps", Json::num(0.75)),
                ("delete_frac", Json::num(delete_frac)),
                ("total_ops", Json::num(total_ops as f64)),
                ("deletes", Json::num(deletes as f64)),
            ]),
        ),
        ("single", Json::obj(single_fields)),
        ("conn_ablation", Json::Arr(ablation)),
        ("chain_churn", chain_section),
        ("snapshot_publish", publish_section),
        ("facade_overhead", facade_section),
        ("obs_overhead", obs_section),
        ("durability", durability_section),
        ("read_path", read_section),
        ("replication", repl_section),
        (
            "single_batched",
            Json::obj(vec![
                ("batch", Json::num(batch as f64)),
                ("wall_s", Json::num(batched_wall)),
                ("ops_per_s", Json::num(batched_ops_s)),
            ]),
        ),
        ("shard_sweep", Json::Arr(shard_rows)),
        ("skew_stress", skew_section),
        (
            "baseline",
            Json::obj(vec![
                (
                    "note",
                    Json::str(
                        "pre-arena (PR 1) single-instance per-op path on the \
                         identical workload (EXPERIMENTS.md §Perf trajectory)",
                    ),
                ),
                ("single_ops_per_s", Json::num(PRE_ARENA_SINGLE_OPS_PER_S)),
                (
                    "speedup_single_vs_baseline",
                    Json::num(single_ops_s / PRE_ARENA_SINGLE_OPS_PER_S),
                ),
            ]),
        ),
    ]);
    write_json(out_path, &record);
    dyn_dbscan::bench_harness::export_json(&record);
    println!("\nwrote {}", out_path.display());
}

// ---------------------------------------------------------------------
// skew-stress axis: placement under a hot spot, reshard off vs auto
// ---------------------------------------------------------------------

/// One op of the skew axis: `Some(coords)` = upsert, `None` = delete.
type SkewOp = (u64, Option<Vec<f32>>);

/// The skew axis stream. `skewed = false`: the standard uniform churn
/// (build_workload) re-expressed with inline coordinates. `skewed = true`:
/// a 40% uniform warm-up (establishes the cell→shard assignment), one
/// point per slot of a 60-step snake far outside the blob box (CellGraph's
/// adjacency voting gloms the contiguous snake cells onto one owner),
/// then the remaining 60% of the stream hammers the same snake — every
/// hot point lands in an already-assigned cell, so sticky first-touch
/// routes the whole hot spot to one shard unless migration intervenes —
/// interleaved with uniform deletes that deepen the imbalance.
fn skew_stress_workload(n: usize, skewed: bool, seed: u64) -> Vec<SkewOp> {
    let (ds, ops) = build_workload(n, 0.2, seed);
    if !skewed {
        return ops
            .iter()
            .map(|op| match *op {
                WlOp::Insert(ext) => (ext, Some(ds.point(ext as usize).to_vec())),
                WlOp::Delete(ext) => (ext, None),
            })
            .collect();
    }
    let warm = n * 2 / 5;
    let snake = |i: usize| -> Vec<f32> {
        let mut p = vec![0.0f32; DIM];
        p[0] = 200.0 + (i % 60) as f32 * 0.3;
        p[1] = 200.0 + ((i / 60) % 7) as f32 * 0.04;
        p
    };
    let mut out: Vec<SkewOp> = Vec::new();
    for i in 0..warm {
        out.push((i as u64, Some(ds.point(i).to_vec())));
    }
    for i in 0..60 {
        out.push(((n + i) as u64, Some(snake(i))));
    }
    let hot = n.saturating_sub(warm);
    for i in 0..hot {
        out.push(((n + 60 + i) as u64, Some(snake(i))));
        if i % 8 == 0 && i / 8 < warm / 4 {
            out.push(((i / 8) as u64, None));
        }
    }
    out
}

/// One cell of the axis: drive `ops` through a direct `ShardedEngine`
/// (publish every 2000 ops, resharding consulted before each publish
/// exactly like the serve façade does) and report throughput plus the
/// final per-shard load spread. Returns `(row, load_max)`.
fn skew_stress_run(
    ops: &[SkewOp],
    shards: usize,
    mode: ReshardMode,
    workload: &str,
    reshard: &str,
) -> (Json, f64) {
    let cfg = DbscanConfig { k: 10, t: 10, eps: 0.75, dim: DIM, ..Default::default() };
    let mut scfg = ShardConfig::new(cfg, shards, 42);
    scfg.reshard = mode;
    let mut eng = ShardedEngine::new(scfg);
    let mut coords: FxHashMap<u64, Vec<f32>> = FxHashMap::default();
    let t0 = Instant::now();
    for chunk in ops.chunks(2_000) {
        for op in chunk {
            match op {
                (ext, Some(c)) => {
                    coords.insert(*ext, c.clone());
                    eng.insert(*ext, c);
                }
                (ext, None) => {
                    coords.remove(ext);
                    eng.delete(*ext);
                }
            }
        }
        eng.maybe_reshard(|ext, buf| match coords.get(&ext) {
            Some(row) => {
                buf.extend_from_slice(row);
                true
            }
            None => false,
        });
        eng.publish();
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let mut loads = eng.metrics().shard_loads();
    loads.truncate(shards);
    let load_max = loads.iter().copied().max().unwrap_or(0) as f64;
    let load_mean =
        loads.iter().copied().sum::<u64>() as f64 / shards.max(1) as f64;
    let epoch = eng.placement_version();
    let stats = eng.stats();
    let (ghost_ratio, migrated) = (stats.ghost_ratio(), stats.migrated_points);
    let _ = eng.finish();
    let row = Json::obj(vec![
        ("workload", Json::str(workload)),
        ("reshard", Json::str(reshard)),
        ("wall_s", Json::num(wall_s)),
        ("ops_per_s", Json::num(ops.len() as f64 / wall_s)),
        ("load_max", Json::num(load_max)),
        ("load_mean", Json::num(load_mean)),
        ("reshard_epoch", Json::num(epoch as f64)),
        ("ghost_ratio", Json::num(ghost_ratio)),
        ("migrated_points", Json::num(migrated as f64)),
    ]);
    (row, load_max)
}

/// The full axis: {uniform, hot-spot} × {off, auto}. The acceptance
/// claim of the resharding PR is the `auto_beats_off_on_skew` field —
/// under the hot-spot stream, migration must end with a lower peak
/// shard load than the frozen assignment (gated at full scale by
/// `validate_updates_json`).
fn skew_stress_section(n: usize, shards: usize) -> Json {
    let mut table = Table::new(
        "skew stress: per-shard load under a hot-spot stream (reshard off vs auto)",
        &["workload", "reshard", "ops/s", "load max", "load mean", "epoch"],
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut skew_max = [0.0f64; 2]; // [off, auto] on the hot-spot stream
    for (wname, skewed) in [("uniform", false), ("hot-spot", true)] {
        let ops = skew_stress_workload(n, skewed, 13);
        for (mi, (mname, mode)) in [
            ("off", ReshardMode::Off),
            ("auto", ReshardMode::Auto { max_cells_per_publish: 16 }),
        ]
        .into_iter()
        .enumerate()
        {
            let (row, load_max) = skew_stress_run(&ops, shards, mode, wname, mname);
            if skewed {
                skew_max[mi] = load_max;
            }
            table.row(vec![
                wname.into(),
                mname.into(),
                format!("{:.0}", row.get("ops_per_s").and_then(|v| v.as_f64()).unwrap_or(0.0)),
                format!("{load_max:.0}"),
                format!(
                    "{:.0}",
                    row.get("load_mean").and_then(|v| v.as_f64()).unwrap_or(0.0)
                ),
                format!(
                    "{:.0}",
                    row.get("reshard_epoch").and_then(|v| v.as_f64()).unwrap_or(0.0)
                ),
            ]);
            rows.push(row);
        }
    }
    table.print();
    Json::obj(vec![
        ("n", Json::num(n as f64)),
        ("shards", Json::num(shards as f64)),
        ("publish_every", Json::num(2_000.0)),
        ("max_cells_per_publish", Json::num(16.0)),
        ("rows", Json::Arr(rows)),
        (
            "auto_beats_off_on_skew",
            Json::num(if skew_max[1] < skew_max[0] { 1.0 } else { 0.0 }),
        ),
    ])
}

// ---------------------------------------------------------------------
// replication: leader shipping tax, read fan-out, bootstrap catch-up
// ---------------------------------------------------------------------

/// Budgeted leader write-path tax of WAL log-shipping (wall-time fraction
/// of a leader with followers attached over the identical persistent run
/// with none, min-of-reps), asserted at full scale. Shipping reads the
/// already-written tail and queues frames on an in-process channel — it
/// must stay well under the fsync it rides behind.
const REPL_OVERHEAD_GATE_FULL: f64 = 0.05;
/// Smoke backstop: tiny runs amortize the per-publish tail read over very
/// few ops and single runs are scheduler-jitter-dominated.
const REPL_OVERHEAD_GATE_SMOKE: f64 = 0.50;

/// The gate that applies to a replication-overhead measurement at
/// workload size `n` (shared by the recorder and the JSON validator).
fn repl_gate(n: f64) -> f64 {
    if n >= 10_000.0 {
        REPL_OVERHEAD_GATE_FULL
    } else {
        REPL_OVERHEAD_GATE_SMOKE
    }
}

/// Stream the churn workload through a replicated leader (publish every
/// 2000 ops, checkpoint every 8 publishes — the `facade_churn_run`
/// cadence). Followers are attached but *not* drained inside the timed
/// loop: the measured wall is exactly the leader's write path including
/// its per-publish ship. Returns (wall s, leader, router).
fn replicated_churn_run(
    ds: &Dataset,
    ops: &[WlOp],
    dir: &std::path::Path,
    followers: usize,
) -> (f64, Box<dyn ClusterEngine>, ReadRouter) {
    let (mut leader, router) = EngineBuilder::new(DIM)
        .seed(42)
        .persist(dir)
        .persist_every(8)
        .replicate(followers)
        .max_staleness(u64::MAX) // reads never force a catch-up here
        .build_replicated()
        .unwrap();
    let t0 = Instant::now();
    for (i, op) in ops.iter().enumerate() {
        match *op {
            WlOp::Insert(ext) => leader.upsert(ext, ds.point(ext as usize)),
            WlOp::Delete(ext) => leader.remove(ext),
        }
        if (i + 1) % 2000 == 0 {
            leader.publish();
        }
    }
    leader.publish();
    (t0.elapsed().as_secs_f64(), leader, router)
}

/// The replication axis: leader write-path overhead at 0/1/2 attached
/// followers (0 = the plain persistent engine, the baseline), aggregate
/// ε-query capacity across the replica set, and bootstrap catch-up time
/// from an incremental chain vs full-only checkpoints.
fn replication_section(ds: &Dataset, ops: &[WlOp], n: usize, reps: usize) -> Json {
    let total_ops = ops.len() as f64;
    let follower_counts = [0usize, 1, 2];
    let mut best = [f64::MAX; 3];
    for rep in 0..reps {
        for (fi, &followers) in follower_counts.iter().enumerate() {
            let dir = persist_scratch(&format!("repl-{rep}-{followers}"));
            let wall = if followers == 0 {
                let (wall, eng) =
                    facade_churn_run(ds, ops, Some((dir.as_path(), 8)));
                let _ = eng.finish();
                wall
            } else {
                let (wall, leader, mut router) =
                    replicated_churn_run(ds, ops, &dir, followers);
                // parity sanity outside the timing: everything the leader
                // published is drainable and lands on its version
                let applied = router.catch_up();
                assert!(applied > 0, "followers never received a frame");
                assert_eq!(
                    router.read().version(),
                    leader.snapshot().version(),
                    "caught-up replica must match the leader version"
                );
                let _ = leader.finish();
                wall
            };
            let _ = std::fs::remove_dir_all(&dir);
            best[fi] = best[fi].min(wall);
        }
    }
    let mut table = Table::new(
        "replication: leader write path vs attached followers (churn)",
        &["followers", "ops/s", "overhead"],
    );
    let mut leader_rows: Vec<Json> = Vec::new();
    for (fi, &followers) in follower_counts.iter().enumerate() {
        let overhead = best[fi] / best[0] - 1.0;
        table.row(vec![
            followers.to_string(),
            format!("{:.0}", total_ops / best[fi]),
            format!("{:+.2}%", overhead * 100.0),
        ]);
        leader_rows.push(Json::obj(vec![
            ("followers", Json::num(followers as f64)),
            ("wall_s", Json::num(best[fi])),
            ("ops_per_s", Json::num(total_ops / best[fi])),
            ("overhead_frac", Json::num(overhead)),
        ]));
    }
    table.print();

    // read fan-out: ε-query QPS of the leader's view and of each caught-up
    // replica's view. Replicas share no mutable state, so the replica
    // set's aggregate capacity is the sum of its members — that sum (vs
    // the leader alone) is the scaling claim recorded here.
    let dir = persist_scratch("repl-read");
    let (_, leader, mut router) = replicated_churn_run(ds, ops, &dir, 2);
    router.catch_up();
    let probes = read_probes(ds, n, 64, 0xD1CE);
    let lv = leader.snapshot();
    let leader_qps = time_queries(&probes, reps, |p| {
        std::hint::black_box(lv.epsilon_neighbors(p));
    });
    let mut replica_qps: Vec<f64> = Vec::new();
    for i in 0..router.len() {
        let rv = router.replica(i).snapshot();
        assert_eq!(rv.version(), lv.version());
        replica_qps.push(time_queries(&probes, reps, |p| {
            std::hint::black_box(rv.epsilon_neighbors(p));
        }));
    }
    let aggregate: f64 = replica_qps.iter().sum();
    let _ = leader.finish();
    drop(router);
    let _ = std::fs::remove_dir_all(&dir);
    let mut scale_table = Table::new(
        "replication: ε-query capacity (leader vs replica set)",
        &["source", "ε qps"],
    );
    scale_table.row(vec!["leader".into(), format!("{leader_qps:.0}")]);
    for (i, q) in replica_qps.iter().enumerate() {
        scale_table.row(vec![format!("replica {i}"), format!("{q:.0}")]);
    }
    scale_table.row(vec!["replica set (sum)".into(), format!("{aggregate:.0}")]);
    scale_table.print();

    // bootstrap catch-up: crash a persistent leader mid-stream, then time
    // how long attaching one follower takes — checkpoint chain (full ⊕
    // delta) vs full-only spills, identical op history
    let mut boot: Vec<Json> = Vec::new();
    let mut boot_table = Table::new(
        "replication: follower bootstrap after leader crash",
        &["checkpoints", "bootstrap s", "tail records replayed"],
    );
    for incremental in [true, false] {
        let dir = persist_scratch(&format!("repl-boot-{incremental}"));
        let mut b = EngineBuilder::new(DIM)
            .seed(42)
            .persist(&dir)
            .persist_every(8)
            .incremental_checkpoints(incremental);
        let mut eng = b.build().unwrap();
        for (i, op) in ops.iter().enumerate() {
            match *op {
                WlOp::Insert(ext) => eng.upsert(ext, ds.point(ext as usize)),
                WlOp::Delete(ext) => eng.remove(ext),
            }
            if (i + 1) % 2000 == 0 {
                eng.publish();
            }
        }
        eng.publish();
        std::mem::forget(eng); // crash: no flush, no shutdown spill
        b = EngineBuilder::new(DIM)
            .seed(42)
            .persist(&dir)
            .persist_every(8)
            .incremental_checkpoints(incremental);
        let t0 = Instant::now();
        let (leader, router) =
            b.replicate(1).max_staleness(0).build_replicated().unwrap();
        let boot_s = t0.elapsed().as_secs_f64();
        let replayed = leader.metrics().wal.replay_records;
        boot_table.row(vec![
            if incremental { "full + delta chain" } else { "full only" }.into(),
            format!("{boot_s:.3}"),
            replayed.to_string(),
        ]);
        boot.push(Json::obj(vec![
            ("incremental", Json::num(if incremental { 1.0 } else { 0.0 })),
            ("bootstrap_s", Json::num(boot_s)),
            ("tail_records_replayed", Json::num(replayed as f64)),
        ]));
        drop(router);
        let _ = leader.finish();
        let _ = std::fs::remove_dir_all(&dir);
    }
    boot_table.print();

    Json::obj(vec![
        ("n", Json::num(n as f64)),
        ("reps", Json::num(reps as f64)),
        ("publish_every", Json::num(2_000.0)),
        ("checkpoint_every_publishes", Json::num(8.0)),
        ("gate_frac", Json::num(repl_gate(n as f64))),
        ("leader", Json::Arr(leader_rows)),
        (
            "read_scaling",
            Json::obj(vec![
                ("probes", Json::num(probes.len() as f64)),
                ("leader_eps_qps", Json::num(leader_qps)),
                (
                    "replica_eps_qps",
                    Json::Arr(replica_qps.iter().map(|&q| Json::num(q)).collect()),
                ),
                ("aggregate_eps_qps", Json::num(aggregate)),
            ]),
        ),
        ("bootstrap", Json::Arr(boot)),
    ])
}

/// Smoke check: the artifact must parse and carry the trajectory fields.
fn validate_updates_json(path: &std::path::Path) {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("cannot read {}: {e}", path.display()));
    let j = Json::parse(text.trim())
        .unwrap_or_else(|e| panic!("{} is not valid JSON: {e}", path.display()));
    let ops_s = j
        .get("single")
        .and_then(|s| s.get("ops_per_s"))
        .and_then(|v| v.as_f64())
        .unwrap_or_else(|| panic!("missing single.ops_per_s in {}", path.display()));
    assert!(ops_s > 0.0, "non-positive single-instance throughput");
    let sweep = j
        .get("shard_sweep")
        .and_then(|v| v.as_arr())
        .unwrap_or_else(|| panic!("missing shard_sweep in {}", path.display()));
    assert!(!sweep.is_empty(), "empty shard_sweep");
    for row in sweep {
        assert!(
            row.get("ops_per_s").and_then(|v| v.as_f64()).unwrap_or(0.0) > 0.0,
            "sharded row missing throughput"
        );
    }
    let ablation = j
        .get("conn_ablation")
        .and_then(|v| v.as_arr())
        .unwrap_or_else(|| panic!("missing conn_ablation in {}", path.display()));
    assert_eq!(ablation.len(), 3, "conn ablation must cover all three modes");
    let chain_modes = j
        .get("chain_churn")
        .and_then(|c| c.get("modes"))
        .and_then(|v| v.as_arr())
        .unwrap_or_else(|| panic!("missing chain_churn.modes in {}", path.display()));
    assert_eq!(chain_modes.len(), 3, "chain churn must cover all three modes");
    for row in chain_modes {
        assert!(
            row.get("delete_p99_ns").and_then(|v| v.as_f64()).unwrap_or(0.0) > 0.0,
            "chain-churn row missing delete p99"
        );
    }
    // façade-overhead axis: both throughputs recorded, tax under the gate
    let fac = j
        .get("facade_overhead")
        .unwrap_or_else(|| panic!("missing facade_overhead in {}", path.display()));
    for field in ["direct_ops_per_s", "facade_ops_per_s"] {
        assert!(
            fac.get(field).and_then(|v| v.as_f64()).unwrap_or(0.0) > 0.0,
            "facade_overhead missing {field}"
        );
    }
    let overhead = fac
        .get("overhead_frac")
        .and_then(|v| v.as_f64())
        .expect("facade_overhead missing overhead_frac");
    // recompute the gate from the recorded n — the ≤2% budget applies
    // at full scale, the jitter backstop at smoke scale
    let gate = facade_gate(fac.get("n").and_then(|v| v.as_f64()).unwrap_or(0.0));
    assert!(
        overhead <= gate,
        "serve façade per-op overhead {:.1}% exceeds the {:.0}% gate",
        overhead * 100.0,
        gate * 100.0
    );

    // obs-overhead axis: same shape — the metrics registry must be
    // effectively free relative to the no-op recorder
    let obs = j
        .get("obs_overhead")
        .unwrap_or_else(|| panic!("missing obs_overhead in {}", path.display()));
    for field in ["metrics_on_ops_per_s", "metrics_off_ops_per_s"] {
        assert!(
            obs.get(field).and_then(|v| v.as_f64()).unwrap_or(0.0) > 0.0,
            "obs_overhead missing {field}"
        );
    }
    let obs_frac = obs
        .get("overhead_frac")
        .and_then(|v| v.as_f64())
        .expect("obs_overhead missing overhead_frac");
    let obs_gate = facade_gate(obs.get("n").and_then(|v| v.as_f64()).unwrap_or(0.0));
    assert!(
        obs_frac <= obs_gate,
        "metrics registry per-op overhead {:.1}% exceeds the {:.0}% gate",
        obs_frac * 100.0,
        obs_gate * 100.0
    );

    // durability axis: steady-state WAL tax under the gate, recovery
    // rows present with real replay work behind them
    let dur = j
        .get("durability")
        .unwrap_or_else(|| panic!("missing durability in {}", path.display()));
    for field in ["persist_off_ops_per_s", "persist_on_ops_per_s"] {
        assert!(
            dur.get(field).and_then(|v| v.as_f64()).unwrap_or(0.0) > 0.0,
            "durability missing {field}"
        );
    }
    let wal_frac = dur
        .get("overhead_frac")
        .and_then(|v| v.as_f64())
        .expect("durability missing overhead_frac");
    let wal_gate_frac = wal_gate(dur.get("n").and_then(|v| v.as_f64()).unwrap_or(0.0));
    assert!(
        wal_frac <= wal_gate_frac,
        "steady-state WAL overhead {:.1}% exceeds the {:.0}% gate",
        wal_frac * 100.0,
        wal_gate_frac * 100.0
    );
    let rec_rows = dur
        .get("recovery")
        .and_then(|v| v.as_arr())
        .unwrap_or_else(|| panic!("missing durability.recovery in {}", path.display()));
    assert!(rec_rows.len() >= 2, "recovery axis needs >= 2 live sizes");
    for row in rec_rows {
        // cold replay re-executes every logged op; checkpoint recovery
        // replays only the tail past the last spill
        let cold = row
            .get("cold_replay_records")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0);
        let tail = row
            .get("ckpt_tail_replay_records")
            .and_then(|v| v.as_f64())
            .unwrap_or(f64::MAX);
        assert!(cold > 0.0, "cold recovery row replayed nothing");
        assert!(
            tail <= cold,
            "checkpoint recovery should replay no more records than cold \
             ({tail} vs {cold})"
        );
    }

    // read-path axis: indexed and scan QPS at both ends of the size
    // span, the asymptotic ε-speedup gate at full scale, and the
    // index-maintenance tax under its gate
    let rp = j
        .get("read_path")
        .unwrap_or_else(|| panic!("missing read_path in {}", path.display()));
    let rp_rows = rp
        .get("sizes")
        .and_then(|v| v.as_arr())
        .unwrap_or_else(|| panic!("missing read_path.sizes in {}", path.display()));
    assert!(rp_rows.len() >= 2, "read-path axis needs >= 2 live sizes");
    let mut rp_lives = Vec::new();
    for row in rp_rows {
        for field in
            ["eps_indexed_qps", "eps_scan_qps", "knn_indexed_qps", "knn_scan_qps"]
        {
            assert!(
                row.get(field).and_then(|v| v.as_f64()).unwrap_or(0.0) > 0.0,
                "read_path row missing {field}"
            );
        }
        rp_lives.push(row.get("live").and_then(|v| v.as_f64()).unwrap_or(0.0));
    }
    if rp_lives.iter().all(|&l| l >= 50_000.0) {
        for row in rp_rows {
            let sp = row.get("eps_speedup").and_then(|v| v.as_f64()).unwrap_or(0.0);
            assert!(
                sp >= EPS_SPEEDUP_GATE_FULL,
                "indexed ε-query speedup {sp:.1}x below the \
                 {EPS_SPEEDUP_GATE_FULL}x gate at full scale"
            );
        }
    }
    let maint = rp
        .get("maintenance_overhead_frac")
        .and_then(|v| v.as_f64())
        .expect("read_path missing maintenance_overhead_frac");
    let maint_gate = read_gate(rp.get("n").and_then(|v| v.as_f64()).unwrap_or(0.0));
    assert!(
        maint <= maint_gate,
        "index maintenance per-op overhead {:.1}% exceeds the {:.0}% gate",
        maint * 100.0,
        maint_gate * 100.0
    );

    // publish-latency axis: both stitch modes at every live size
    let pub_rows = j
        .get("snapshot_publish")
        .and_then(|p| p.get("sizes"))
        .and_then(|v| v.as_arr())
        .unwrap_or_else(|| {
            panic!("missing snapshot_publish.sizes in {}", path.display())
        });
    assert!(pub_rows.len() >= 2, "publish axis needs >= 2 live sizes");
    let mut lives = Vec::new();
    let mut delta_p99 = Vec::new();
    let mut rebuild_p99 = Vec::new();
    for row in pub_rows {
        for field in ["delta_publish_p99_ns", "rebuild_publish_p99_ns"] {
            assert!(
                row.get(field).and_then(|v| v.as_f64()).unwrap_or(0.0) > 0.0,
                "snapshot_publish row missing {field}"
            );
        }
        lives.push(row.get("live").and_then(|v| v.as_f64()).unwrap_or(0.0));
        delta_p99
            .push(row.get("delta_publish_p99_ns").and_then(|v| v.as_f64()).unwrap());
        rebuild_p99
            .push(row.get("rebuild_publish_p99_ns").and_then(|v| v.as_f64()).unwrap());
    }
    // The delta-snapshot acceptance gate, on full-scale axes only (smoke
    // sizes are scheduler-jitter-dominated): delta p99 stays inside a
    // ±20% band across live sizes (max/min ≤ 1.5) while the rebuild p99
    // grows with the live set (≥ 3× over a ≥ 4× size span).
    let full_scale = lives.iter().all(|&l| l >= 50_000.0);
    let size_span = lives.last().unwrap() / lives.first().unwrap();
    if full_scale && size_span >= 4.0 {
        let (lo, hi) = delta_p99
            .iter()
            .fold((f64::MAX, 0.0f64), |(lo, hi), &x| (lo.min(x), hi.max(x)));
        assert!(
            hi <= lo * 1.5,
            "delta publish p99 not flat across live sizes: {delta_p99:?}"
        );
        assert!(
            *rebuild_p99.last().unwrap() >= rebuild_p99[0] * 3.0,
            "full rebuild p99 should grow with live points: {rebuild_p99:?}"
        );
    }

    // skew-stress axis: all four cells recorded, and at full scale the
    // acceptance claim of the resharding PR — Auto ends the hot-spot
    // stream with a lower peak shard load than the frozen assignment
    let skew = j
        .get("skew_stress")
        .unwrap_or_else(|| panic!("missing skew_stress in {}", path.display()));
    let skew_rows = skew
        .get("rows")
        .and_then(|v| v.as_arr())
        .unwrap_or_else(|| panic!("missing skew_stress.rows in {}", path.display()));
    assert_eq!(
        skew_rows.len(),
        4,
        "skew axis must cover uniform/hot-spot x off/auto"
    );
    for row in skew_rows {
        assert!(
            row.get("ops_per_s").and_then(|v| v.as_f64()).unwrap_or(0.0) > 0.0,
            "skew-stress row missing throughput"
        );
        let load_max = row.get("load_max").and_then(|v| v.as_f64()).unwrap_or(-1.0);
        let load_mean = row.get("load_mean").and_then(|v| v.as_f64()).unwrap_or(0.0);
        assert!(
            load_max >= load_mean,
            "skew-stress row has an impossible load spread \
             (max {load_max} < mean {load_mean})"
        );
    }
    let skew_n = skew.get("n").and_then(|v| v.as_f64()).unwrap_or(0.0);
    if skew_n >= 10_000.0 {
        assert_eq!(
            skew.get("auto_beats_off_on_skew").and_then(|v| v.as_f64()),
            Some(1.0),
            "auto resharding failed to beat the frozen assignment under skew"
        );
    }

    // replication axis: the follower sweep is complete, the leader's
    // shipping tax is inside the budget for the recorded n, and the
    // fan-out + bootstrap measurements carry non-degenerate numbers
    let repl = j
        .get("replication")
        .unwrap_or_else(|| panic!("missing replication in {}", path.display()));
    let repl_n = repl.get("n").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let gate = repl_gate(repl_n);
    let leader_rows = repl
        .get("leader")
        .and_then(|v| v.as_arr())
        .unwrap_or_else(|| panic!("missing replication.leader in {}", path.display()));
    assert_eq!(
        leader_rows.len(),
        3,
        "replication leader sweep must cover 0/1/2 followers"
    );
    for row in leader_rows {
        assert!(
            row.get("ops_per_s").and_then(|v| v.as_f64()).unwrap_or(0.0) > 0.0,
            "replication leader row missing throughput"
        );
        let followers =
            row.get("followers").and_then(|v| v.as_f64()).unwrap_or(-1.0);
        let overhead = row
            .get("overhead_frac")
            .and_then(|v| v.as_f64())
            .unwrap_or(f64::MAX);
        assert!(
            overhead <= gate,
            "log-shipping tax at {followers} followers is {:.1}% \
             (gate {:.0}% at n={repl_n})",
            overhead * 100.0,
            gate * 100.0
        );
    }
    let scaling = repl.get("read_scaling").unwrap_or_else(|| {
        panic!("missing replication.read_scaling in {}", path.display())
    });
    let replica_qps = scaling
        .get("replica_eps_qps")
        .and_then(|v| v.as_arr())
        .unwrap_or_else(|| panic!("missing replica_eps_qps in {}", path.display()));
    assert_eq!(replica_qps.len(), 2, "read scaling must cover both replicas");
    let aggregate = scaling
        .get("aggregate_eps_qps")
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0);
    let worst_replica = replica_qps
        .iter()
        .map(|v| v.as_f64().unwrap_or(0.0))
        .fold(f64::MAX, f64::min);
    assert!(
        worst_replica > 0.0 && aggregate >= worst_replica,
        "replica read fan-out is degenerate (aggregate {aggregate}, \
         worst replica {worst_replica})"
    );
    let boot = repl
        .get("bootstrap")
        .and_then(|v| v.as_arr())
        .unwrap_or_else(|| panic!("missing replication.bootstrap in {}", path.display()));
    assert_eq!(boot.len(), 2, "bootstrap must cover incremental and full");
    for row in boot {
        assert!(
            row.get("bootstrap_s").and_then(|v| v.as_f64()).unwrap_or(0.0) > 0.0,
            "bootstrap row missing wall time"
        );
    }
}

// ---------------------------------------------------------------------
// insert-only shard sweep (BENCH_shard.json, from the sharding PR)
// ---------------------------------------------------------------------

/// Insert-stream throughput: single-instance `DynamicDbscan` vs
/// `ShardedEngine` at S ∈ {1, 2, 4, 8} on the same synthetic stream.
/// The sharded wall time includes routing, channel transport and the
/// final stitch barrier — it is the end-to-end serving cost.
fn shard_sweep(n: usize) {
    // wide center box: the 24 clusters spread over ~10 blocks per routing
    // axis, so block→shard hashing balances and ghost zones stay thin
    let ds: Dataset = make_blobs(
        &BlobsConfig {
            n,
            dim: DIM,
            clusters: 24,
            std: 0.3,
            center_box: 60.0,
            weights: vec![],
        },
        7,
    );
    let cfg = DbscanConfig { k: 10, t: 10, eps: 0.75, dim: DIM, ..Default::default() };

    // single-instance baseline (the per-op path, no pipeline overhead)
    let t0 = Instant::now();
    let mut db = DynamicDbscan::new(cfg.clone(), 42);
    for i in 0..ds.n() {
        db.add_point(ds.point(i));
    }
    let single_s = t0.elapsed().as_secs_f64();
    let single_ups = n as f64 / single_s;
    std::hint::black_box(db.num_core_points());

    let mut table = Table::new(
        "shard sweep: 1 insert stream, single vs ShardedEngine",
        &["shards", "wall s", "updates/s", "speedup", "ghost ratio", "clusters"],
    );
    table.row(vec![
        "single".into(),
        format!("{single_s:.2}"),
        format!("{single_ups:.0}"),
        "1.00".into(),
        "0.00".into(),
        "-".into(),
    ]);

    let mut sweep_rows: Vec<Json> = Vec::new();
    for &shards in &[1usize, 2, 4, 8] {
        let scfg = ShardConfig::new(cfg.clone(), shards, 42);
        let mut eng = ShardedEngine::new(scfg);
        let t0 = Instant::now();
        for i in 0..ds.n() {
            eng.insert(i as u64, ds.point(i));
            if (i + 1) % 1000 == 0 {
                eng.flush();
            }
        }
        eng.flush();
        let snap = eng.publish(); // barrier: every op applied + stitched
        let wall_s = t0.elapsed().as_secs_f64();
        let out = eng.finish();
        let ups = n as f64 / wall_s;
        let speedup = single_s / wall_s;
        let ghost_ratio = out.stats.ghost_ratio();
        table.row(vec![
            shards.to_string(),
            format!("{wall_s:.2}"),
            format!("{ups:.0}"),
            format!("{speedup:.2}"),
            format!("{ghost_ratio:.2}"),
            snap.clusters.to_string(),
        ]);
        sweep_rows.push(Json::obj(vec![
            ("shards", Json::num(shards as f64)),
            ("wall_s", Json::num(wall_s)),
            ("updates_per_s", Json::num(ups)),
            ("speedup_vs_single", Json::num(speedup)),
            ("ghost_ratio", Json::num(ghost_ratio)),
            ("clusters", Json::num(snap.clusters as f64)),
        ]));
    }
    table.print();

    let record = Json::obj(vec![
        ("bench", Json::str("shard_sweep")),
        ("n", Json::num(n as f64)),
        ("dim", Json::num(DIM as f64)),
        ("clusters", Json::num(24.0)),
        ("single_wall_s", Json::num(single_s)),
        ("single_updates_per_s", Json::num(single_ups)),
        ("sweep", Json::Arr(sweep_rows)),
    ]);
    let path = repo_root_file("BENCH_shard.json");
    write_json(&path, &record);
    dyn_dbscan::bench_harness::export_json(&record);
    println!("\nwrote {}", path.display());
}
