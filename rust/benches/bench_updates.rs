//! Ablation A3: per-operation update cost vs n — the empirical check of
//! Theorem 1's `O(d log³n + log⁴n)` claim, plus the eager-attach extension
//! and repair-mode overhead.
//!
//! For each n the structure is pre-filled with n points, then the marginal
//! cost of 2000 further inserts and 2000 deletes is measured. A polylog
//! bound predicts near-flat per-op times across decades of n (vs the
//! linear growth a per-batch static rebuild exhibits).
//!
//! Also runs the **shard sweep**: one insert stream through
//! `ShardedEngine` at S ∈ {1, 2, 4, 8} against the single-instance
//! baseline, recording wall-clock throughput, speedup and ghost-replication
//! overhead to `BENCH_shard.json` (the scaling trajectory every later
//! perf PR appends to).
//!
//! ```bash
//! cargo bench --bench bench_updates
//! ```

use std::time::Instant;

use dyn_dbscan::bench_harness::{write_json, Table};
use dyn_dbscan::data::blobs::{make_blobs, BlobsConfig};
use dyn_dbscan::data::Dataset;
use dyn_dbscan::dbscan::{DbscanConfig, DynamicDbscan, PaperConn, RepairConn};
use dyn_dbscan::ett::SkipForest;
use dyn_dbscan::shard::{ShardConfig, ShardedEngine};
use dyn_dbscan::util::json::Json;
use dyn_dbscan::util::rng::Rng;

const DIM: usize = 10;

fn gen_point(rng: &mut Rng) -> Vec<f32> {
    let c = rng.below(10) as f64 * 1.2;
    (0..DIM).map(|_| (c + rng.uniform(-0.6, 0.6)) as f32).collect()
}

struct Probe {
    add_us: f64,
    del_us: f64,
    searches: u64,
    visited: u64,
}

fn probe_mode(n: usize, eager: bool, paper_exact: bool, seed: u64) -> Probe {
    let cfg = DbscanConfig {
        k: 10,
        t: 10,
        eps: 0.75,
        dim: DIM,
        eager_attach: eager,
    };
    macro_rules! run {
        ($db:expr) => {{
            let mut db = $db;
            let mut rng = Rng::new(seed);
            let mut live: Vec<u64> = Vec::with_capacity(n + 4000);
            for _ in 0..n {
                live.push(db.add_point(&gen_point(&mut rng)));
            }
            let probes = 2000;
            let t0 = std::time::Instant::now();
            let mut added = Vec::with_capacity(probes);
            for _ in 0..probes {
                added.push(db.add_point(&gen_point(&mut rng)));
            }
            let add_us = t0.elapsed().as_secs_f64() * 1e6 / probes as f64;
            // delete a random mix of old and new points
            let t0 = std::time::Instant::now();
            for i in 0..probes {
                let p = if i % 2 == 0 {
                    added.pop().unwrap()
                } else {
                    let j = rng.below_usize(live.len());
                    live.swap_remove(j)
                };
                db.delete_point(p);
            }
            let del_us = t0.elapsed().as_secs_f64() * 1e6 / probes as f64;
            let st = db.repair_stats();
            Probe { add_us, del_us, searches: st.searches, visited: st.visited }
        }};
    }
    if paper_exact {
        run!(DynamicDbscan::with_conn(
            cfg,
            seed,
            PaperConn::new(SkipForest::new(seed ^ 1))
        ))
    } else {
        run!(DynamicDbscan::with_conn(
            cfg,
            seed,
            RepairConn::new(SkipForest::new(seed ^ 1))
        ))
    }
}

fn main() {
    let mut table = Table::new(
        "A3: per-op update cost vs n (µs/op; polylog ⇒ near-flat)",
        &[
            "n",
            "add µs",
            "del µs",
            "add µs (eager)",
            "del µs (eager)",
            "add µs (paper-exact)",
            "repl searches",
            "visited/search",
        ],
    );
    let quick = std::env::var("FULL").map(|v| v != "1").unwrap_or(true);
    let sizes: &[usize] = if quick {
        &[1_000, 4_000, 16_000, 64_000]
    } else {
        &[1_000, 4_000, 16_000, 64_000, 200_000]
    };
    for &n in sizes {
        let base = probe_mode(n, false, false, 42);
        let eager = probe_mode(n, true, false, 42);
        let paper = probe_mode(n, false, true, 42);
        let vps = if base.searches > 0 {
            format!("{:.1}", base.visited as f64 / base.searches as f64)
        } else {
            "0".into()
        };
        table.row(vec![
            n.to_string(),
            format!("{:.1}", base.add_us),
            format!("{:.1}", base.del_us),
            format!("{:.1}", eager.add_us),
            format!("{:.1}", eager.del_us),
            format!("{:.1}", paper.add_us),
            base.searches.to_string(),
            vps,
        ]);
    }
    table.print();
    dyn_dbscan::bench_harness::export_json(&table.to_json());

    shard_sweep(if quick { 50_000 } else { 200_000 });
}

/// Insert-stream throughput: single-instance `DynamicDbscan` vs
/// `ShardedEngine` at S ∈ {1, 2, 4, 8} on the same synthetic stream.
/// The sharded wall time includes routing, channel transport and the
/// final stitch barrier — it is the end-to-end serving cost.
fn shard_sweep(n: usize) {
    // wide center box: the 24 clusters spread over ~10 blocks per routing
    // axis, so block→shard hashing balances and ghost zones stay thin
    let ds: Dataset = make_blobs(
        &BlobsConfig {
            n,
            dim: DIM,
            clusters: 24,
            std: 0.3,
            center_box: 60.0,
            weights: vec![],
        },
        7,
    );
    let cfg = DbscanConfig { k: 10, t: 10, eps: 0.75, dim: DIM, ..Default::default() };

    // single-instance baseline (the per-op path, no pipeline overhead)
    let t0 = Instant::now();
    let mut db = DynamicDbscan::new(cfg.clone(), 42);
    for i in 0..ds.n() {
        db.add_point(ds.point(i));
    }
    let single_s = t0.elapsed().as_secs_f64();
    let single_ups = n as f64 / single_s;
    std::hint::black_box(db.num_core_points());

    let mut table = Table::new(
        "shard sweep: 1 insert stream, single vs ShardedEngine",
        &["shards", "wall s", "updates/s", "speedup", "ghost ratio", "clusters"],
    );
    table.row(vec![
        "single".into(),
        format!("{single_s:.2}"),
        format!("{single_ups:.0}"),
        "1.00".into(),
        "0.00".into(),
        "-".into(),
    ]);

    let mut sweep_rows: Vec<Json> = Vec::new();
    for &shards in &[1usize, 2, 4, 8] {
        let scfg = ShardConfig::new(cfg.clone(), shards, 42);
        let mut eng = ShardedEngine::new(scfg);
        let t0 = Instant::now();
        for i in 0..ds.n() {
            eng.insert(i as u64, ds.point(i));
            if (i + 1) % 1000 == 0 {
                eng.flush();
            }
        }
        eng.flush();
        let snap = eng.publish(); // barrier: every op applied + stitched
        let wall_s = t0.elapsed().as_secs_f64();
        let out = eng.finish();
        let ups = n as f64 / wall_s;
        let speedup = single_s / wall_s;
        let ghost_ratio = out.stats.ghost_ratio();
        table.row(vec![
            shards.to_string(),
            format!("{wall_s:.2}"),
            format!("{ups:.0}"),
            format!("{speedup:.2}"),
            format!("{ghost_ratio:.2}"),
            snap.clusters.to_string(),
        ]);
        sweep_rows.push(Json::obj(vec![
            ("shards", Json::num(shards as f64)),
            ("wall_s", Json::num(wall_s)),
            ("updates_per_s", Json::num(ups)),
            ("speedup_vs_single", Json::num(speedup)),
            ("ghost_ratio", Json::num(ghost_ratio)),
            ("clusters", Json::num(snap.clusters as f64)),
        ]));
    }
    table.print();

    let record = Json::obj(vec![
        ("bench", Json::str("shard_sweep")),
        ("n", Json::num(n as f64)),
        ("dim", Json::num(DIM as f64)),
        ("clusters", Json::num(24.0)),
        ("single_wall_s", Json::num(single_s)),
        ("single_updates_per_s", Json::num(single_ups)),
        ("sweep", Json::Arr(sweep_rows)),
    ]);
    write_json("BENCH_shard.json", &record);
    dyn_dbscan::bench_harness::export_json(&record);
    println!("\nwrote BENCH_shard.json");
}
