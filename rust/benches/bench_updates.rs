//! Update-path benchmarks.
//!
//! 1. **Ablation A3**: per-operation update cost vs n — the empirical check
//!    of Theorem 1's `O(d log³n + log⁴n)` claim, plus the eager-attach
//!    extension and repair-mode overhead. For each n the structure is
//!    pre-filled with n points, then the marginal cost of 2000 further
//!    inserts and 2000 deletes is measured.
//! 2. **Update throughput** (→ `BENCH_updates.json` at the repo root): the
//!    standard streaming-blobs churn workload (k=10, t=10, ε=0.75, n=50k,
//!    20% deletes) through the single-instance per-op path, the batched
//!    `apply_batch` path, and the sharded engine at S ∈ {1, 2, 4, 8} —
//!    ops/sec plus p50/p99 add & delete latency. This file is the perf
//!    trajectory every later PR measures against.
//! 3. **Shard sweep** (insert-only, → `BENCH_shard.json`): kept from the
//!    sharding PR for continuity.
//!
//! ```bash
//! cargo bench --bench bench_updates            # full run
//! cargo bench --bench bench_updates -- --smoke # tiny n, validates JSON
//! ```

use std::time::Instant;

use dyn_dbscan::bench_harness::{repo_root_file, write_json, Table};
use dyn_dbscan::data::blobs::{make_blobs, BlobsConfig};
use dyn_dbscan::data::Dataset;
use dyn_dbscan::dbscan::{DbscanConfig, DynamicDbscan, Op, PaperConn, RepairConn};
use dyn_dbscan::ett::SkipForest;
use dyn_dbscan::shard::{ShardConfig, ShardedEngine};
use dyn_dbscan::util::json::Json;
use dyn_dbscan::util::rng::Rng;
use dyn_dbscan::util::stats::LatencyHisto;
use rustc_hash::{FxHashMap, FxHashSet};

const DIM: usize = 10;

/// Pre-arena (PR 1) single-instance per-op throughput on the standard
/// churn workload (n=50k), recorded in EXPERIMENTS.md §Perf trajectory —
/// the fixed reference the trajectory's speedup field is computed against.
const PRE_ARENA_SINGLE_OPS_PER_S: f64 = 31_010.0;

fn gen_point(rng: &mut Rng) -> Vec<f32> {
    let c = rng.below(10) as f64 * 1.2;
    (0..DIM).map(|_| (c + rng.uniform(-0.6, 0.6)) as f32).collect()
}

struct Probe {
    add_us: f64,
    del_us: f64,
    searches: u64,
    visited: u64,
}

fn probe_mode(n: usize, eager: bool, paper_exact: bool, seed: u64) -> Probe {
    let cfg = DbscanConfig {
        k: 10,
        t: 10,
        eps: 0.75,
        dim: DIM,
        eager_attach: eager,
    };
    macro_rules! run {
        ($db:expr) => {{
            let mut db = $db;
            let mut rng = Rng::new(seed);
            let mut live: Vec<u64> = Vec::with_capacity(n + 4000);
            for _ in 0..n {
                live.push(db.add_point(&gen_point(&mut rng)));
            }
            let probes = 2000;
            let t0 = std::time::Instant::now();
            let mut added = Vec::with_capacity(probes);
            for _ in 0..probes {
                added.push(db.add_point(&gen_point(&mut rng)));
            }
            let add_us = t0.elapsed().as_secs_f64() * 1e6 / probes as f64;
            // delete a random mix of old and new points
            let t0 = std::time::Instant::now();
            for i in 0..probes {
                let p = if i % 2 == 0 {
                    added.pop().unwrap()
                } else {
                    let j = rng.below_usize(live.len());
                    live.swap_remove(j)
                };
                db.delete_point(p);
            }
            let del_us = t0.elapsed().as_secs_f64() * 1e6 / probes as f64;
            let st = db.repair_stats();
            Probe { add_us, del_us, searches: st.searches, visited: st.visited }
        }};
    }
    if paper_exact {
        run!(DynamicDbscan::with_conn(
            cfg,
            seed,
            PaperConn::new(SkipForest::new(seed ^ 1))
        ))
    } else {
        run!(DynamicDbscan::with_conn(
            cfg,
            seed,
            RepairConn::new(SkipForest::new(seed ^ 1))
        ))
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    if smoke {
        // tiny end-to-end pass: runs the throughput bench and validates the
        // JSON artifact it writes (the CI gate for the perf trajectory).
        // Writes to a scratch path so a local smoke run never clobbers the
        // committed full-scale BENCH_updates.json.
        let path = std::env::temp_dir().join("BENCH_updates.smoke.json");
        update_throughput(1_500, &[1, 2], &path);
        validate_updates_json(&path);
        println!("smoke OK: {} is valid", path.display());
        return;
    }

    let mut table = Table::new(
        "A3: per-op update cost vs n (µs/op; polylog ⇒ near-flat)",
        &[
            "n",
            "add µs",
            "del µs",
            "add µs (eager)",
            "del µs (eager)",
            "add µs (paper-exact)",
            "repl searches",
            "visited/search",
        ],
    );
    let quick = std::env::var("FULL").map(|v| v != "1").unwrap_or(true);
    let sizes: &[usize] = if quick {
        &[1_000, 4_000, 16_000, 64_000]
    } else {
        &[1_000, 4_000, 16_000, 64_000, 200_000]
    };
    for &n in sizes {
        let base = probe_mode(n, false, false, 42);
        let eager = probe_mode(n, true, false, 42);
        let paper = probe_mode(n, false, true, 42);
        let vps = if base.searches > 0 {
            format!("{:.1}", base.visited as f64 / base.searches as f64)
        } else {
            "0".into()
        };
        table.row(vec![
            n.to_string(),
            format!("{:.1}", base.add_us),
            format!("{:.1}", base.del_us),
            format!("{:.1}", eager.add_us),
            format!("{:.1}", eager.del_us),
            format!("{:.1}", paper.add_us),
            base.searches.to_string(),
            vps,
        ]);
    }
    table.print();
    dyn_dbscan::bench_harness::export_json(&table.to_json());

    let n = if quick { 50_000 } else { 200_000 };
    update_throughput(n, &[1, 2, 4, 8], &repo_root_file("BENCH_updates.json"));
    shard_sweep(n);
}

// ---------------------------------------------------------------------
// update throughput: the standard churn workload → BENCH_updates.json
// ---------------------------------------------------------------------

/// One op of the churn workload; `ext` is the dataset row.
#[derive(Clone, Copy, Debug)]
enum WlOp {
    Insert(u64),
    Delete(u64),
}

/// Streaming-blobs churn: insert every dataset row once, interleaving
/// deletes of uniformly random live points so that `delete_frac` of all
/// ops are deletes. Deterministic in the seed.
fn build_workload(n: usize, delete_frac: f64, seed: u64) -> (Dataset, Vec<WlOp>) {
    let ds = make_blobs(
        &BlobsConfig {
            n,
            dim: DIM,
            clusters: 24,
            std: 0.3,
            center_box: 60.0,
            weights: vec![],
        },
        seed,
    );
    let mut rng = Rng::new(seed ^ 0x51C);
    let mut ops = Vec::new();
    let mut live: Vec<u64> = Vec::new();
    let mut next_row = 0usize;
    while next_row < n {
        if !live.is_empty() && rng.coin(delete_frac) {
            let i = rng.below_usize(live.len());
            ops.push(WlOp::Delete(live.swap_remove(i)));
        } else {
            ops.push(WlOp::Insert(next_row as u64));
            live.push(next_row as u64);
            next_row += 1;
        }
    }
    (ds, ops)
}

struct SingleRun {
    wall_s: f64,
    add: LatencyHisto,
    del: LatencyHisto,
}

/// Per-op path: one `DynamicDbscan`, one call per op.
fn run_single(ds: &Dataset, ops: &[WlOp], cfg: &DbscanConfig, seed: u64) -> SingleRun {
    let mut db = DynamicDbscan::new(cfg.clone(), seed);
    let mut ext_map: FxHashMap<u64, u64> = FxHashMap::default();
    let mut add = LatencyHisto::new();
    let mut del = LatencyHisto::new();
    let t0 = Instant::now();
    for op in ops {
        match *op {
            WlOp::Insert(ext) => {
                let o0 = Instant::now();
                let pid = db.add_point(ds.point(ext as usize));
                add.record(o0.elapsed().as_nanos() as u64);
                ext_map.insert(ext, pid);
            }
            WlOp::Delete(ext) => {
                let pid = ext_map.remove(&ext).expect("workload delete of dead ext");
                let o0 = Instant::now();
                db.delete_point(pid);
                del.record(o0.elapsed().as_nanos() as u64);
            }
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    std::hint::black_box(db.num_core_points());
    SingleRun { wall_s, add, del }
}

/// Batched path: the same op stream through `apply_batch` in chunks. A
/// delete of a point added in the still-pending chunk flushes first (its
/// pid is unknown until the batch applies).
fn run_single_batched(
    ds: &Dataset,
    ops: &[WlOp],
    cfg: &DbscanConfig,
    seed: u64,
    batch: usize,
) -> f64 {
    let mut db = DynamicDbscan::new(cfg.clone(), seed);
    let mut ext_map: FxHashMap<u64, u64> = FxHashMap::default();
    let mut pending: Vec<Op> = Vec::with_capacity(batch);
    let mut pending_exts: Vec<u64> = Vec::with_capacity(batch);
    let mut in_pending: FxHashSet<u64> = FxHashSet::default();
    let t0 = Instant::now();
    macro_rules! flush {
        () => {{
            let ids = db.apply_batch(&pending);
            debug_assert_eq!(ids.len(), pending_exts.len());
            for (&ext, pid) in pending_exts.iter().zip(ids) {
                ext_map.insert(ext, pid);
            }
            pending.clear();
            pending_exts.clear();
            in_pending.clear();
        }};
    }
    for op in ops {
        match *op {
            WlOp::Insert(ext) => {
                pending.push(Op::Add(ds.point(ext as usize)));
                pending_exts.push(ext);
                in_pending.insert(ext);
            }
            WlOp::Delete(ext) => {
                if in_pending.contains(&ext) {
                    flush!();
                }
                let pid = *ext_map.get(&ext).expect("workload delete of dead ext");
                ext_map.remove(&ext);
                pending.push(Op::Delete(pid));
            }
        }
        if pending.len() >= batch {
            flush!();
        }
    }
    flush!();
    let wall_s = t0.elapsed().as_secs_f64();
    std::hint::black_box(db.num_core_points());
    wall_s
}

fn histo_json(h: &LatencyHisto) -> Vec<(&'static str, Json)> {
    vec![
        ("p50_ns", Json::num(h.quantile(0.5) as f64)),
        ("p99_ns", Json::num(h.quantile(0.99) as f64)),
        ("mean_ns", Json::num(h.mean())),
    ]
}

/// Run the churn workload on every engine configuration and write the
/// trajectory record to `out_path` (the repo-root `BENCH_updates.json` in
/// full runs, a scratch file under `--smoke`).
fn update_throughput(n: usize, shard_counts: &[usize], out_path: &std::path::Path) {
    let cfg = DbscanConfig { k: 10, t: 10, eps: 0.75, dim: DIM, ..Default::default() };
    let delete_frac = 0.2;
    let (ds, ops) = build_workload(n, delete_frac, 7);
    let total_ops = ops.len();
    let deletes = ops.iter().filter(|o| matches!(o, WlOp::Delete(_))).count();

    let mut table = Table::new(
        "update throughput: streaming-blobs churn (20% deletes)",
        &["engine", "wall s", "ops/s", "add p50/p99 µs", "del p50/p99 µs"],
    );

    // single-instance, per-op
    let single = run_single(&ds, &ops, &cfg, 42);
    let single_ops_s = total_ops as f64 / single.wall_s;
    table.row(vec![
        "single".into(),
        format!("{:.2}", single.wall_s),
        format!("{single_ops_s:.0}"),
        format!(
            "{:.1}/{:.1}",
            single.add.quantile(0.5) as f64 / 1e3,
            single.add.quantile(0.99) as f64 / 1e3
        ),
        format!(
            "{:.1}/{:.1}",
            single.del.quantile(0.5) as f64 / 1e3,
            single.del.quantile(0.99) as f64 / 1e3
        ),
    ]);

    // single-instance, batched ingestion
    let batch = 512usize;
    let batched_wall = run_single_batched(&ds, &ops, &cfg, 42, batch);
    let batched_ops_s = total_ops as f64 / batched_wall;
    table.row(vec![
        format!("single (apply_batch {batch})"),
        format!("{batched_wall:.2}"),
        format!("{batched_ops_s:.0}"),
        "-".into(),
        "-".into(),
    ]);

    // sharded engine
    let mut shard_rows: Vec<Json> = Vec::new();
    for &shards in shard_counts {
        let scfg = ShardConfig::new(cfg.clone(), shards, 42);
        let mut eng = ShardedEngine::new(scfg);
        let t0 = Instant::now();
        for (i, op) in ops.iter().enumerate() {
            match *op {
                WlOp::Insert(ext) => eng.insert(ext, ds.point(ext as usize)),
                WlOp::Delete(ext) => eng.delete(ext),
            }
            if (i + 1) % 1000 == 0 {
                eng.flush();
            }
        }
        eng.flush();
        let snap = eng.publish(); // barrier: every op applied + stitched
        let wall_s = t0.elapsed().as_secs_f64();
        let out = eng.finish();
        let ops_s = total_ops as f64 / wall_s;
        table.row(vec![
            format!("sharded S={shards}"),
            format!("{wall_s:.2}"),
            format!("{ops_s:.0}"),
            format!(
                "{:.1}/{:.1}",
                out.add_latency.quantile(0.5) as f64 / 1e3,
                out.add_latency.quantile(0.99) as f64 / 1e3
            ),
            format!(
                "{:.1}/{:.1}",
                out.delete_latency.quantile(0.5) as f64 / 1e3,
                out.delete_latency.quantile(0.99) as f64 / 1e3
            ),
        ]);
        let mut fields = vec![
            ("shards", Json::num(shards as f64)),
            ("wall_s", Json::num(wall_s)),
            ("ops_per_s", Json::num(ops_s)),
            ("speedup_vs_single", Json::num(single.wall_s / wall_s)),
            ("ghost_ratio", Json::num(out.stats.ghost_ratio())),
            ("clusters", Json::num(snap.clusters as f64)),
        ];
        for (k, v) in histo_json(&out.add_latency) {
            fields.push(match k {
                "p50_ns" => ("add_p50_ns", v),
                "p99_ns" => ("add_p99_ns", v),
                _ => ("add_mean_ns", v),
            });
        }
        for (k, v) in histo_json(&out.delete_latency) {
            fields.push(match k {
                "p50_ns" => ("delete_p50_ns", v),
                "p99_ns" => ("delete_p99_ns", v),
                _ => ("delete_mean_ns", v),
            });
        }
        shard_rows.push(Json::obj(fields));
    }
    table.print();

    let mut single_fields = vec![
        ("wall_s", Json::num(single.wall_s)),
        ("ops_per_s", Json::num(single_ops_s)),
    ];
    for (k, v) in histo_json(&single.add) {
        single_fields.push(match k {
            "p50_ns" => ("add_p50_ns", v),
            "p99_ns" => ("add_p99_ns", v),
            _ => ("add_mean_ns", v),
        });
    }
    for (k, v) in histo_json(&single.del) {
        single_fields.push(match k {
            "p50_ns" => ("delete_p50_ns", v),
            "p99_ns" => ("delete_p99_ns", v),
            _ => ("delete_mean_ns", v),
        });
    }
    let record = Json::obj(vec![
        ("bench", Json::str("updates_throughput")),
        (
            "workload",
            Json::obj(vec![
                ("name", Json::str("streaming-blobs-churn")),
                ("n", Json::num(n as f64)),
                ("dim", Json::num(DIM as f64)),
                ("k", Json::num(10.0)),
                ("t", Json::num(10.0)),
                ("eps", Json::num(0.75)),
                ("delete_frac", Json::num(delete_frac)),
                ("total_ops", Json::num(total_ops as f64)),
                ("deletes", Json::num(deletes as f64)),
            ]),
        ),
        ("single", Json::obj(single_fields)),
        (
            "single_batched",
            Json::obj(vec![
                ("batch", Json::num(batch as f64)),
                ("wall_s", Json::num(batched_wall)),
                ("ops_per_s", Json::num(batched_ops_s)),
            ]),
        ),
        ("shard_sweep", Json::Arr(shard_rows)),
        (
            "baseline",
            Json::obj(vec![
                (
                    "note",
                    Json::str(
                        "pre-arena (PR 1) single-instance per-op path on the \
                         identical workload (EXPERIMENTS.md §Perf trajectory)",
                    ),
                ),
                ("single_ops_per_s", Json::num(PRE_ARENA_SINGLE_OPS_PER_S)),
                (
                    "speedup_single_vs_baseline",
                    Json::num(single_ops_s / PRE_ARENA_SINGLE_OPS_PER_S),
                ),
            ]),
        ),
    ]);
    write_json(out_path, &record);
    dyn_dbscan::bench_harness::export_json(&record);
    println!("\nwrote {}", out_path.display());
}

/// Smoke check: the artifact must parse and carry the trajectory fields.
fn validate_updates_json(path: &std::path::Path) {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("cannot read {}: {e}", path.display()));
    let j = Json::parse(text.trim())
        .unwrap_or_else(|e| panic!("{} is not valid JSON: {e}", path.display()));
    let ops_s = j
        .get("single")
        .and_then(|s| s.get("ops_per_s"))
        .and_then(|v| v.as_f64())
        .unwrap_or_else(|| panic!("missing single.ops_per_s in {}", path.display()));
    assert!(ops_s > 0.0, "non-positive single-instance throughput");
    let sweep = j
        .get("shard_sweep")
        .and_then(|v| v.as_arr())
        .unwrap_or_else(|| panic!("missing shard_sweep in {}", path.display()));
    assert!(!sweep.is_empty(), "empty shard_sweep");
    for row in sweep {
        assert!(
            row.get("ops_per_s").and_then(|v| v.as_f64()).unwrap_or(0.0) > 0.0,
            "sharded row missing throughput"
        );
    }
}

// ---------------------------------------------------------------------
// insert-only shard sweep (BENCH_shard.json, from the sharding PR)
// ---------------------------------------------------------------------

/// Insert-stream throughput: single-instance `DynamicDbscan` vs
/// `ShardedEngine` at S ∈ {1, 2, 4, 8} on the same synthetic stream.
/// The sharded wall time includes routing, channel transport and the
/// final stitch barrier — it is the end-to-end serving cost.
fn shard_sweep(n: usize) {
    // wide center box: the 24 clusters spread over ~10 blocks per routing
    // axis, so block→shard hashing balances and ghost zones stay thin
    let ds: Dataset = make_blobs(
        &BlobsConfig {
            n,
            dim: DIM,
            clusters: 24,
            std: 0.3,
            center_box: 60.0,
            weights: vec![],
        },
        7,
    );
    let cfg = DbscanConfig { k: 10, t: 10, eps: 0.75, dim: DIM, ..Default::default() };

    // single-instance baseline (the per-op path, no pipeline overhead)
    let t0 = Instant::now();
    let mut db = DynamicDbscan::new(cfg.clone(), 42);
    for i in 0..ds.n() {
        db.add_point(ds.point(i));
    }
    let single_s = t0.elapsed().as_secs_f64();
    let single_ups = n as f64 / single_s;
    std::hint::black_box(db.num_core_points());

    let mut table = Table::new(
        "shard sweep: 1 insert stream, single vs ShardedEngine",
        &["shards", "wall s", "updates/s", "speedup", "ghost ratio", "clusters"],
    );
    table.row(vec![
        "single".into(),
        format!("{single_s:.2}"),
        format!("{single_ups:.0}"),
        "1.00".into(),
        "0.00".into(),
        "-".into(),
    ]);

    let mut sweep_rows: Vec<Json> = Vec::new();
    for &shards in &[1usize, 2, 4, 8] {
        let scfg = ShardConfig::new(cfg.clone(), shards, 42);
        let mut eng = ShardedEngine::new(scfg);
        let t0 = Instant::now();
        for i in 0..ds.n() {
            eng.insert(i as u64, ds.point(i));
            if (i + 1) % 1000 == 0 {
                eng.flush();
            }
        }
        eng.flush();
        let snap = eng.publish(); // barrier: every op applied + stitched
        let wall_s = t0.elapsed().as_secs_f64();
        let out = eng.finish();
        let ups = n as f64 / wall_s;
        let speedup = single_s / wall_s;
        let ghost_ratio = out.stats.ghost_ratio();
        table.row(vec![
            shards.to_string(),
            format!("{wall_s:.2}"),
            format!("{ups:.0}"),
            format!("{speedup:.2}"),
            format!("{ghost_ratio:.2}"),
            snap.clusters.to_string(),
        ]);
        sweep_rows.push(Json::obj(vec![
            ("shards", Json::num(shards as f64)),
            ("wall_s", Json::num(wall_s)),
            ("updates_per_s", Json::num(ups)),
            ("speedup_vs_single", Json::num(speedup)),
            ("ghost_ratio", Json::num(ghost_ratio)),
            ("clusters", Json::num(snap.clusters as f64)),
        ]));
    }
    table.print();

    let record = Json::obj(vec![
        ("bench", Json::str("shard_sweep")),
        ("n", Json::num(n as f64)),
        ("dim", Json::num(DIM as f64)),
        ("clusters", Json::num(24.0)),
        ("single_wall_s", Json::num(single_s)),
        ("single_updates_per_s", Json::num(single_ups)),
        ("sweep", Json::Arr(sweep_rows)),
    ]);
    let path = repo_root_file("BENCH_shard.json");
    write_json(&path, &record);
    dyn_dbscan::bench_harness::export_json(&record);
    println!("\nwrote {}", path.display());
}
