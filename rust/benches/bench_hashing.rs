//! Ablation A2: hashing engines — native scalar Rust vs the AOT
//! Pallas/XLA artifact through PJRT, on identical inputs, across the
//! Table-1 dimensionalities.
//!
//! ```bash
//! cargo bench --bench bench_hashing
//! ```
//!
//! Expected shape: the XLA path pays a per-dispatch cost (~100 µs on CPU
//! PJRT) amortized over the compiled batch of 1024 points; the native path
//! has no dispatch cost. On CPU the native path wins; the artifact path
//! exists to validate the three-layer architecture and to model the TPU
//! deployment where the quantizer rides along with larger fused graphs.

use dyn_dbscan::bench_harness::{bench, Table};
use dyn_dbscan::lsh::GridHasher;
use dyn_dbscan::runtime::engines::{HashingEngine, NativeHashing, XlaHashing};
use dyn_dbscan::runtime::Runtime;
use dyn_dbscan::util::rng::Rng;

fn main() {
    let dir = Runtime::default_dir();
    let have_xla = Runtime::available(&dir);
    if !have_xla {
        eprintln!("warning: no artifacts at {dir:?}; run `make artifacts` for the XLA column");
    }
    let mut table = Table::new(
        "A2: hashing engine ablation (points/s, batch=1024, t=10)",
        &["d", "native pts/s", "xla pts/s", "xla/native"],
    );
    let n = 16 * 1024;
    let runs = 5;
    for &d in &[10usize, 16, 20, 54] {
        let mut rng = Rng::new(5);
        let xs: Vec<f32> = (0..n * d).map(|_| rng.next_f32() * 8.0 - 4.0).collect();
        let hasher = GridHasher::new(10, d, 0.75, 42);

        let mut native = NativeHashing::new(hasher.clone());
        let mn = bench("native", 1, runs, || {
            std::hint::black_box(native.keys_batch(&xs, n).unwrap());
        });
        let native_pps = n as f64 / mn.mean_s;

        let (xla_pps, ratio) = if have_xla {
            let rt = Runtime::new(&dir).expect("runtime");
            match XlaHashing::new(rt, hasher.clone()) {
                Ok(mut xla) => {
                    let mx = bench("xla", 1, runs, || {
                        std::hint::black_box(xla.keys_batch(&xs, n).unwrap());
                    });
                    let pps = n as f64 / mx.mean_s;
                    (format!("{pps:.0}"), format!("{:.3}", pps / native_pps))
                }
                Err(e) => (format!("n/a ({e})"), "-".into()),
            }
        } else {
            ("n/a".into(), "-".into())
        };
        table.row(vec![
            d.to_string(),
            format!("{native_pps:.0}"),
            xla_pps,
            ratio,
        ]);
    }
    table.print();
    dyn_dbscan::bench_harness::export_json(&table.to_json());
}
