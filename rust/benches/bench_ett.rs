//! Ablation A1: Euler-tour sequence backends — skip list (Tseng et al.,
//! the paper's choice) vs treap (Henzinger–King) vs the naive DFS forest.
//!
//! Workloads: (i) random link/cut churn on n vertices, (ii) path build +
//! teardown, (iii) root-query storms on large components — the three
//! access patterns Algorithm 2 generates.
//!
//! ```bash
//! cargo bench --bench bench_ett
//! ```

use dyn_dbscan::bench_harness::{bench, Table};
use dyn_dbscan::ett::naive::NaiveForest;
use dyn_dbscan::ett::{Forest, SkipForest, TreapForest};
use dyn_dbscan::util::rng::Rng;

fn churn<F: Forest>(f: &mut F, n: usize, ops: usize, seed: u64) -> u64 {
    let vs: Vec<u32> = (0..n).map(|_| f.add_vertex()).collect();
    let mut rng = Rng::new(seed);
    let mut edges: Vec<(u32, u32)> = Vec::new();
    let mut acc = 0u64;
    for _ in 0..ops {
        match rng.below(10) {
            0..=4 => {
                let a = vs[rng.below_usize(n)];
                let b = vs[rng.below_usize(n)];
                if a != b && f.link(a, b) {
                    edges.push((a, b));
                }
            }
            5..=7 => {
                if !edges.is_empty() {
                    let i = rng.below_usize(edges.len());
                    let (a, b) = edges.swap_remove(i);
                    f.cut(a, b);
                }
            }
            _ => {
                acc ^= f.root(vs[rng.below_usize(n)]);
            }
        }
    }
    acc
}

fn path_cycle<F: Forest>(f: &mut F, n: usize) -> u64 {
    let vs: Vec<u32> = (0..n).map(|_| f.add_vertex()).collect();
    for w in vs.windows(2) {
        f.link(w[0], w[1]);
    }
    let r = f.root(vs[n / 2]);
    for w in vs.windows(2) {
        f.cut(w[0], w[1]);
    }
    r
}

fn root_storm<F: Forest>(f: &mut F, n: usize, queries: usize, seed: u64) -> u64 {
    let vs: Vec<u32> = (0..n).map(|_| f.add_vertex()).collect();
    for w in vs.windows(2) {
        f.link(w[0], w[1]);
    }
    let mut rng = Rng::new(seed);
    let mut acc = 0u64;
    for _ in 0..queries {
        acc ^= f.root(vs[rng.below_usize(n)]);
    }
    acc
}

fn main() {
    let mut table = Table::new(
        "A1: Euler-tour backend ablation (mean s ± stderr)",
        &["workload", "n", "skiplist", "treap", "naive"],
    );
    let runs = 5;
    for &n in &[1_000usize, 10_000, 50_000] {
        let ops = n * 4;
        let s = bench("skip", 1, runs, || {
            let mut f = SkipForest::new(1);
            std::hint::black_box(churn(&mut f, n, ops, 7));
        });
        let t = bench("treap", 1, runs, || {
            let mut f = TreapForest::new(1);
            std::hint::black_box(churn(&mut f, n, ops, 7));
        });
        // naive is O(n) per op — only measure at the small size
        let nv = if n <= 1_000 {
            let m = bench("naive", 0, 2, || {
                let mut f = NaiveForest::new();
                std::hint::black_box(churn(&mut f, n, ops, 7));
            });
            m.fmt_seconds()
        } else {
            "-".to_string()
        };
        table.row(vec![
            format!("churn x{ops}"),
            n.to_string(),
            s.fmt_seconds(),
            t.fmt_seconds(),
            nv,
        ]);
    }
    for &n in &[10_000usize, 100_000] {
        let s = bench("skip", 1, runs, || {
            let mut f = SkipForest::new(1);
            std::hint::black_box(path_cycle(&mut f, n));
        });
        let t = bench("treap", 1, runs, || {
            let mut f = TreapForest::new(1);
            std::hint::black_box(path_cycle(&mut f, n));
        });
        table.row(vec![
            "path build+teardown".into(),
            n.to_string(),
            s.fmt_seconds(),
            t.fmt_seconds(),
            "-".into(),
        ]);
    }
    for &n in &[100_000usize] {
        let q = 1_000_000;
        let s = bench("skip", 1, runs, || {
            let mut f = SkipForest::new(1);
            std::hint::black_box(root_storm(&mut f, n, q, 3));
        });
        let t = bench("treap", 1, runs, || {
            let mut f = TreapForest::new(1);
            std::hint::black_box(root_storm(&mut f, n, q, 3));
        });
        table.row(vec![
            format!("root storm x{q}"),
            n.to_string(),
            s.fmt_seconds(),
            t.fmt_seconds(),
            "-".into(),
        ]);
    }
    table.print();
    dyn_dbscan::bench_harness::export_json(&table.to_json());
}
